"""SLO observatory (repro.obs.slo + repro.obs.attrib): declarative SLO
specs, online burn-rate monitoring on the modeled cycle clock, span-based
miss attribution, integer-exact online/offline reconciliation on a single
gateway and a >=4-shard fabric, router observability counters, the
streaming replay twin, and the capacity planner smoke."""
import pytest
from _hypothesis_compat import given, settings, st
from test_gateway import FakeAdapter

from repro.core import cycle_model as cm
from repro.obs import (
    ATTRIB_CLASSES,
    RecordingSink,
    SloMonitor,
    SloSpec,
    TeeSink,
    assemble,
    attribute,
    attribution_shares,
    classify_segments,
    find_monitor,
    span_misses,
)
from repro.obs.slo import FLEET
from repro.serve.fabric import Fabric
from repro.serve.gateway import Gateway
from repro.workload import arrivals, from_streams
from repro.workload import replay as replay_mod


def _cost_mat(treq, seed, idx):
    return treq.payload["cost"], {}


def mk_gateway(*, policy="fair", sink=None, unit=300, slots=3,
               round_budget=2_000, shares=None):
    return Gateway(
        [FakeAdapter("a", slots=slots, unit=unit),
         FakeAdapter("b", slots=slots, unit=unit)],
        policy=policy, round_budget=round_budget,
        shares=shares or {"a": 0.5, "b": 0.5},
        sink=sink,
    )


def mk_deadline_trace(seed=13, n_a=14, n_b=9, *, tight=2_500, loose=9_000):
    """The obs probe trace with per-class deadlines: class ``a`` tight
    enough that queueing shows up as misses, ``b`` loose."""
    return from_streams(
        "slo_probe", seed,
        [
            dict(kind="a", qos="a",
                 arrivals=arrivals.poisson(n_a, mean_interval=900,
                                           seed=seed),
                 payload=lambda i: dict(cost=400 + 150 * (i % 5)),
                 deadline_cycles=tight),
            dict(kind="b", qos="b",
                 arrivals=arrivals.on_off(n_b, seed=seed + 1,
                                          burst_interval=200, on_mean=900,
                                          off_mean=3_000),
                 payload=dict(cost=1_200), deadline_cycles=loose),
        ],
    )


def mk_fabric(n=4, *, sink=None, seed=23, router="deficit", policy="fair"):
    return Fabric(
        [mk_gateway(policy=policy) for _ in range(n)],
        router=router, seed=seed, sink=sink,
    )


def replay_once(target, trace, **kw):
    return replay_mod.replay(target, trace, {"a": _cost_mat, "b": _cost_mat},
                             **kw)


SPECS = (SloSpec("a", pct=99, latency_target_ms=0.02, miss_budget=0.1),
         SloSpec("b", pct=99, miss_budget=0.25))


# ------------------------------------------------------------- SloSpec


def test_slo_spec_validation_and_cycles():
    s = SloSpec("interactive", pct=99, latency_target_ms=6.0,
                miss_budget=0.05)
    assert s.latency_target_cycles == int(round(6.0 * cm.FREQ_HZ / 1e3))
    d = s.to_dict()
    assert d["qos"] == "interactive" and d["miss_budget"] == 0.05
    assert SloSpec("x").latency_target_cycles is None
    with pytest.raises(ValueError):
        SloSpec("x", pct=0)
    with pytest.raises(ValueError):
        SloSpec("x", pct=101)
    with pytest.raises(ValueError):
        SloSpec("x", miss_budget=0.0)
    with pytest.raises(ValueError):
        SloSpec("x", miss_budget=1.5)
    with pytest.raises(ValueError):
        SloSpec("x", latency_target_ms=-1.0)


# ------------------------------------------------- attribution classifier


def test_classify_segments_dominance_and_ties():
    assert classify_segments(100, 10, 10) == "queued"
    assert classify_segments(10, 10, 100) == "preempted"
    assert classify_segments(10, 100, 10) == "service"
    # overdraft trumps everything: negative preemption residual means the
    # request ran past its granted budget
    assert classify_segments(1_000, 10, -1) == "overdraft"
    # ties resolve queued > preempted > service
    assert classify_segments(50, 50, 50) == "queued"
    assert classify_segments(10, 50, 50) == "preempted"
    assert classify_segments(0, 0, 0) == "queued"


def test_attribute_and_shares_on_real_spans():
    rec = RecordingSink()
    gw = mk_gateway(sink=rec)
    replay_once(gw, mk_deadline_trace())
    spans = assemble(rec.events)
    misses = span_misses(spans)
    assert misses  # the tight class must miss on this probe
    hist = attribute(spans)
    assert set(misses) == set(hist)
    for qos, h in hist.items():
        assert set(h) == set(ATTRIB_CLASSES)
        assert sum(h.values()) == misses[qos]
        shares = attribution_shares(h)
        assert abs(sum(shares.values()) - 1.0) < 1e-9
    # a clean class yields all-zero shares
    assert attribution_shares(dict.fromkeys(ATTRIB_CLASSES, 0)) == \
        dict.fromkeys(ATTRIB_CLASSES, 0.0)


# ------------------------------- online/offline exactness: gateway


@given(st.integers(0, 10_000),
       st.sampled_from(["fifo", "fair", "edf"]))
@settings(max_examples=12, deadline=None)
def test_gateway_online_offline_miss_reconciliation(seed, policy):
    """The tentpole gate: the online SloMonitor's cumulative per-class
    miss counts AND attribution histograms equal the offline
    span-derived ones, to the integer, on any seed x policy."""
    mon = SloMonitor(SPECS)
    rec = RecordingSink()
    gw = mk_gateway(policy=policy, sink=TeeSink([rec, mon]))
    summary = replay_once(gw, mk_deadline_trace(seed=seed))
    r = mon.reconcile(assemble(rec.events))
    assert r["holds"], r
    # and the gateway's own stats() counters agree with both
    stats_misses = {
        q: c["deadline_misses"]
        for q, c in summary["per_class"].items() if c["deadline_misses"]
    }
    assert stats_misses == r["online"] == r["offline"]
    assert summary["deadline_misses"] == sum(r["online"].values())


# ------------------------------- online/offline exactness: fabric


@given(st.integers(0, 10_000),
       st.sampled_from(["class", "p2c", "deficit"]))
@settings(max_examples=10, deadline=None)
def test_fabric_online_offline_miss_reconciliation(seed, router):
    """Same gate on a 4-shard fabric: shard-tagged events, routing,
    work stealing and export re-keying must not break the integer
    equality."""
    mon = SloMonitor(SPECS)
    rec = RecordingSink()
    fab = mk_fabric(4, sink=TeeSink([rec, mon]), seed=seed % 97, router=router)
    summary = replay_once(fab, mk_deadline_trace(seed=seed, n_a=28, n_b=18))
    r = mon.reconcile(assemble(rec.events))
    assert r["holds"], r
    stats_misses = {
        q: c["deadline_misses"]
        for q, c in summary["per_class"].items() if c["deadline_misses"]
    }
    assert stats_misses == r["online"]
    # fleet scope aggregates the per-shard scopes exactly
    per_shard = [mon.miss_counts(s) for s in mon.scopes() if s != FLEET]
    fleet = {}
    for d in per_shard:
        for q, v in d.items():
            fleet[q] = fleet.get(q, 0) + v
    assert fleet == mon.miss_counts(FLEET)


def test_monitor_tracks_nothing_untracked_on_clean_run():
    mon = SloMonitor(SPECS)
    gw = mk_gateway(sink=mon)
    replay_once(gw, mk_deadline_trace())
    assert mon.in_flight() == 0
    for c in mon.summary()["per_class"].values():
        assert c["untracked"] == 0


# ----------------------------------------------------- burn-rate windows


def test_burn_rates_windows_and_budget_scaling():
    mon = SloMonitor(SPECS, windows=(2_000, 16_000))
    gw = mk_gateway(sink=mon)
    replay_once(gw, mk_deadline_trace())
    br = mon.burn_rates("a")
    assert set(br["windows"]) == {"2000", "16000"}
    pc = mon.summary()["per_class"]["a"]
    n, miss = pc["completions"], pc["deadline_misses"]
    assert pc["miss_rate"] == pytest.approx(miss / n)
    # cumulative burn is miss rate over budget — budget 0.1 for class a
    assert br["cumulative"] == pytest.approx((miss / n) / 0.1)
    # windowed burn rates are nonnegative and finite
    for v in br["windows"].values():
        assert v >= 0.0


def test_stats_slo_block_present_iff_monitor_armed():
    mon = SloMonitor(SPECS)
    gw = mk_gateway(sink=mon)
    replay_once(gw, mk_deadline_trace())
    st_ = gw.stats()
    # a bare gateway's events carry no shard tag: its scope is None
    assert "slo" in st_ and st_["slo"]["scope"] is None
    assert set(st_["slo"]["per_class"]) <= {"a", "b"}

    bare = mk_gateway()
    replay_once(bare, mk_deadline_trace())
    assert "slo" not in bare.stats()

    fab = mk_fabric(4, sink=SloMonitor(SPECS))
    replay_once(fab, mk_deadline_trace())
    assert fab.stats()["slo"]["scope"] == FLEET


def test_find_monitor_unwraps_sink_trees():
    mon = SloMonitor(SPECS)
    assert find_monitor(mon) == (mon, None)
    assert find_monitor(TeeSink([RecordingSink(), mon])) == (mon, None)
    from repro.obs import NULL_SINK, ShardSink
    m, shard = find_monitor(ShardSink(TeeSink([mon]), 3))
    assert m is mon and shard == 3
    assert find_monitor(NULL_SINK) == (None, None)


# ------------------------------------------------- router observability


def test_fabric_router_stats_and_route_events():
    rec = RecordingSink(etypes=["route", "steal"])
    fab = mk_fabric(4, sink=rec, router="p2c")
    summary = replay_once(fab, mk_deadline_trace(n_a=28, n_b=18))
    rs = fab.stats()["router_stats"]
    assert rs["router"] == "p2c"
    assert rs["decided"] == summary["per_class"]["a"]["n"] + \
        summary["per_class"]["b"]["n"]
    assert rs["chose_shallower"] + rs["tie"] <= rs["decided"]
    assert rs["depth_gap_sum"] >= 0
    routes = [e for e in rec.events if e.etype == "route"]
    assert len(routes) == rs["decided"]
    for e in routes:
        assert "q" in e.data and "dst" in e.data
        if "alt" in e.data:  # the losing p2c draw, with its queue depth
            assert e.data["alt"] != e.data["dst"]
            assert e.data["alt_q"] >= e.data["q"] - 0  # depths recorded
    steals = [e for e in rec.events if e.etype == "steal"]
    for e in steals:  # stealing only fires donor-queue -> idle shard
        assert e.data["src_q"] >= 1 and e.data["dst_q"] == 0


def test_class_router_emits_no_alternatives():
    rec = RecordingSink(etypes=["route"])
    fab = mk_fabric(4, sink=rec, router="class")
    replay_once(fab, mk_deadline_trace())
    assert rec.events and all("alt" not in e.data for e in rec.events)
    assert fab.stats()["router_stats"]["router"] == "class"


# ------------------------------------------------------- replay_stream


def test_replay_stream_matches_materialized_replay():
    """The lazy feed and the materialized trace replay are the same
    open-loop schedule: identical per-class stats to the integer."""
    trace = mk_deadline_trace()
    gw_t = mk_gateway()
    s_t = replay_once(gw_t, trace)

    def feed():
        for idx, tr in enumerate(trace.requests):
            payload, _ = _cost_mat(tr, trace.seed, idx)
            kw = dict(qos=tr.qos)
            if tr.deadline_cycles is not None:
                kw["deadline_cycles"] = tr.deadline_cycles
            yield tr.arrival_cycle, tr.kind, payload, kw

    gw_s = mk_gateway()
    s_s = replay_mod.replay_stream(gw_s, feed(), label="twin")
    assert s_s["stream"]["n_requests"] == len(trace)
    assert s_s["per_class"] == s_t["per_class"]
    assert s_s["deadline_misses"] == s_t["deadline_misses"]
    assert s_s["clock_cycles"] == s_t["clock_cycles"]
    assert s_s["rows"][0][0].startswith("stream/twin/")


# ------------------------------------------------- capacity planner smoke


def test_capacity_planner_smoke_tiny_grid(tmp_path):
    """A reduced sweep through the real planner: gates run (including
    the integer reconcile on the instrumented point), the payload lands
    with frontier + attribution shares."""
    import json

    from benchmarks import capacity

    out = tmp_path / "BENCH_capacity.json"
    rows = capacity.run(json_path=str(out), shard_counts=(2, 4),
                        routers=("deficit",), policies=("fair",),
                        plans=("uniform8",))
    assert rows and all(r[0].startswith("capacity/") for r in rows)
    d = json.loads(out.read_text())
    assert d["bench"] == "capacity" and d["gate"]["holds"]
    assert d["gate"]["reconcile"]["holds"]
    labels = [r["label"] for r in d["rows"]]
    assert labels == ["uniform8/deficit-fair/s2", "uniform8/deficit-fair/s4"]
    s2, s4 = d["rows"]
    # fixed load: every point fed the identical stream
    assert d["workload"]["n_offered"] > 0
    assert s4["queue_share"] <= s2["queue_share"]
    f = d["frontier"][0]
    assert f["min_shards"] == 4 and f["gops_w"] == s4["gops_w"]
    assert set(f["attribution_shares"]) <= {"interactive", "batch", "seg"}
