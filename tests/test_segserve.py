"""Tiled-segmentation serving: halo math, exact tiled-vs-whole equivalence
(property, over sizes and depths), content-adaptive tile budgets never
exceeding the layer schedule's certified bound, engine micro-batching, and
the satellite guards (schedule length validation, conv pad modes)."""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.plane_schedule import PlaneSchedule
from repro.models import unet
from repro.segserve import SegEngine, adaptive, tiling


# ------------------------------------------------------------- halo math


def test_halo_known_values():
    """Hand-walked invalid-margin recurrences (see tiling.py docstring)."""
    assert tiling.halo_for(1, 1) == 6    # margin 5 -> ceil to mult 2
    assert tiling.halo_for(2, 1) == 12   # margin 11 -> mult 4
    assert tiling.halo_for(3, 1) == 24   # margin 23 -> mult 8
    assert tiling.halo_for(2, 2) == 24   # margin 22 -> mult 4
    assert tiling.halo_for(0, 1) == 1    # conv-only stack: 1 px, no pooling


def test_halo_alignment_and_monotonicity():
    for c in (1, 2, 3):
        halos = [tiling.halo_for(d, c) for d in range(5)]
        for d, h in enumerate(halos):
            if d:
                assert h % 2**d == 0
        assert all(a <= b for a, b in zip(halos, halos[1:]))
    for d in (1, 2, 3):
        h_by_c = [tiling.halo_for(d, c) for c in (1, 2, 3)]
        assert all(a <= b for a, b in zip(h_by_c, h_by_c[1:]))


def test_halo_validation():
    with pytest.raises(ValueError):
        tiling.halo_for(-1, 1)
    with pytest.raises(ValueError):
        tiling.halo_for(2, 0)


# ----------------------------------------------------------- tile planning


@given(st.integers(5, 70), st.integers(5, 70), st.integers(1, 2))
@settings(max_examples=20, deadline=None)
def test_plan_partitions_canvas(h, w, depth):
    """Cores tile the padded canvas exactly once; every input window is
    in-bounds, aligned to 2**depth, and contains its core."""
    plan = tiling.plan_tiles(h, w, depth=depth, tile=8)
    mult = 2**depth
    assert plan.pad_h % mult == 0 and plan.pad_w % mult == 0
    assert plan.pad_h - h < mult and plan.pad_w - w < mult
    cover = np.zeros((plan.pad_h, plan.pad_w), np.int32)
    for t in plan.tiles:
        cover[t.core_y0 : t.core_y1, t.core_x0 : t.core_x1] += 1
        assert 0 <= t.y0 <= t.core_y0 < t.core_y1 <= t.y1 <= plan.pad_h
        assert 0 <= t.x0 <= t.core_x0 < t.core_x1 <= t.x1 <= plan.pad_w
        for v in (t.y0, t.x0, t.y1, t.x1, t.core_y0, t.core_x0):
            assert v % mult == 0
        assert t.in_h % mult == 0 and t.in_w % mult == 0
    assert bool(np.all(cover == 1))


def test_plan_validation_and_halo_override():
    with pytest.raises(ValueError):
        tiling.plan_tiles(0, 8, depth=1, tile=8)
    with pytest.raises(ValueError):
        tiling.plan_tiles(8, 8, depth=2, tile=6)  # not a multiple of 4
    with pytest.raises(ValueError):
        tiling.plan_tiles(8, 8, depth=1, tile=8, halo=-1)
    # explicit halos round up to the alignment unit; 0 stays 0
    assert tiling.plan_tiles(16, 16, depth=2, tile=8, halo=5).halo == 8
    assert tiling.plan_tiles(16, 16, depth=2, tile=8, halo=0).halo == 0
    # default is the exact receptive-field halo
    assert tiling.plan_tiles(16, 16, depth=2, tile=8).halo == tiling.halo_for(2, 1)


def test_stitch_validation():
    plan = tiling.plan_tiles(8, 8, depth=1, tile=8)
    with pytest.raises(ValueError):
        tiling.stitch(plan, [])
    with pytest.raises(ValueError):
        tiling.stitch(plan, [np.zeros((3, 3, 2), np.float32)])


# ------------------------------------------- tiled-vs-whole equivalence


@functools.lru_cache(maxsize=8)
def _net(depth, base=4, in_ch=3, n_classes=3, **kw):
    cfg = unet.UNetConfig(hw=16, in_ch=in_ch, base=base, depth=depth,
                          convs_per_stage=1, n_classes=n_classes, **kw)
    return cfg, unet.init_params(jax.random.PRNGKey(0), cfg)


def _whole_ref(params, image, cfg):
    """forward on the 2**depth-aligned canvas, cropped to the image."""
    mult = 2**cfg.depth
    h, w = image.shape[:2]
    pad = np.pad(image, ((0, -h % mult), (0, -w % mult), (0, 0)))
    out = unet.forward(params, jnp.asarray(pad[None]), cfg)
    return np.asarray(out[0])[:h, :w]


@given(st.integers(7, 40), st.integers(7, 40), st.integers(1, 2))
@settings(max_examples=8, deadline=None)
def test_tiled_forward_matches_whole(h, w, depth):
    """The acceptance property: halo-exact tiling of an arbitrary-size
    image equals the whole-image forward within fp tolerance."""
    cfg, params = _net(depth)
    image = np.asarray(
        jax.random.normal(jax.random.PRNGKey(h * 101 + w), (h, w, cfg.in_ch))
    )
    got, plan = tiling.tiled_forward(params, image, cfg, tile=8)
    assert got.shape == (h, w, cfg.n_classes)
    assert plan.halo == tiling.halo_for(depth, 1)
    np.testing.assert_allclose(got, _whole_ref(params, image, cfg),
                               rtol=1e-4, atol=1e-4)


def test_tiled_forward_short_halo_is_inexact():
    """Sanity check that the halo is load-bearing: a halo one alignment
    unit short of exact leaves seam error; the exact halo leaves none."""
    cfg, params = _net(2)
    image = np.asarray(jax.random.normal(jax.random.PRNGKey(3), (24, 24, 3)))
    want = _whole_ref(params, image, cfg)
    exact, _ = tiling.tiled_forward(params, image, cfg, tile=8)
    short, _ = tiling.tiled_forward(
        params, image, cfg, tile=8, halo=tiling.halo_for(2, 1) - 4
    )
    np.testing.assert_allclose(exact, want, rtol=1e-4, atol=1e-4)
    assert float(np.max(np.abs(short - want))) > 1e-3


# ------------------------------------------------- adaptive tile budgets


@given(st.lists(st.integers(1, 8), min_size=1, max_size=12),
       st.integers(1, 1000))
@settings(max_examples=25, deadline=None)
def test_refine_never_exceeds_certified_bound(planes, r_milli):
    """The satellite guarantee: a refined tile budget's worst-case error,
    scaled by the tile's amplitude ratio, never exceeds the layer
    schedule's certified bound (2^d - 1 in weight-colsum units)."""
    base = PlaneSchedule.from_list(planes)
    r = r_milli / 1000.0
    ref = base.refine(r)
    assert len(ref) == len(base)
    for b0, b1 in zip(base.planes, ref.planes):
        assert 1 <= b1 <= b0  # refinement only drops digits
        d0, d1 = 8 - b0, 8 - b1
        assert (2**d1 - 1) * r <= (2**d0 - 1)  # certified-budget invariant
        if d0 == 0:
            assert d1 == 0  # full-precision layers are never refined
        if d1 > d0:
            # maximality: one more dropped digit would break the budget
            assert (2 ** (d1 + 1) - 1) * r > (2**d0 - 1) or b1 == 1


def test_refine_identity_and_validation():
    s = PlaneSchedule.from_list([8, 5, 3])
    assert s.refine(1.0).planes == s.planes
    with pytest.raises(ValueError):
        s.refine(1.5)
    with pytest.raises(ValueError):
        s.refine(-0.25)
    # monotone: quieter tiles never get more planes
    prev = None
    for k in range(7):
        p = s.refine(2.0**-k).planes
        if prev is not None:
            assert all(a <= b for a, b in zip(p, prev))
        prev = p


def test_refine_edge_cases():
    """Satellite guards: flat-zero windows, non-finite ratios, the 1-plane
    floor, and per-layer ratio vectors."""
    s = PlaneSchedule.from_list([8, 5, 3, 1])
    # r = 0 (exactly flat window) refines maximally but never below 1 plane
    # and never touches full-precision (zero-budget) layers
    assert s.refine(0.0).planes == (8, 1, 1, 1)
    # non-finite ratios are calibration bugs — refuse loudly
    for bad in (float("nan"), float("inf"), -float("inf")):
        with pytest.raises(ValueError, match="not finite"):
            s.refine(bad)
    # a tiny-but-positive ratio also bottoms out at 1 plane
    assert all(b >= 1 for b in s.refine(1e-30).planes)
    # per-layer measured ratios: each layer refined at its own ratio
    per_layer = s.refine([1.0, 1.0, 0.25, 0.0])
    assert per_layer.planes == (8, 5, s.refine(0.25).planes[2], 1)
    with pytest.raises(ValueError, match="per layer"):
        s.refine([0.5, 0.5])


def test_refine_then_refine_never_exceeds_parent_certificate():
    """Chained refinement stays inside the parent schedule's certified
    budget at the product ratio: refine(r1).refine(r2) drops no more than
    the parent inequality allows at r1*r2."""
    for planes in ([8, 6, 4, 2], [7, 7, 7], [5, 1]):
        s = PlaneSchedule.from_list(planes)
        for r1 in (1.0, 0.5, 0.3, 0.01):
            for r2 in (1.0, 0.5, 0.125, 0.0):
                chained = s.refine(r1).refine(r2)
                for b0, b2 in zip(s.planes, chained.planes):
                    d0, d2 = 8 - b0, 8 - b2
                    # the parent certificate at the product ratio
                    assert (2**d2 - 1) * r1 * r2 <= (2**d0 - 1)
                    if d0 == 0:
                        assert d2 == 0
                # refining in one shot at the product is at least as deep
                one_shot = s.refine(r1 * r2)
                assert all(
                    c >= o for c, o in zip(chained.planes, one_shot.planes)
                )


def test_budget_class_edges():
    assert adaptive.budget_class(1.0) == 0
    assert adaptive.budget_class(0.6) == 0
    assert adaptive.budget_class(0.5) == 1
    assert adaptive.budget_class(0.25) == 2
    assert adaptive.budget_class(0.0) == adaptive.MAX_CLASS
    assert adaptive.budget_class(1e-9, max_class=4) == 4
    with pytest.raises(ValueError):
        adaptive.budget_class(1.5)
    base = PlaneSchedule.from_list([6, 4])
    assert adaptive.class_schedule(base, 0) is base
    assert adaptive.class_schedule(base, 3).planes == base.refine(0.125).planes


def test_classify_tiles_flat_background():
    plan = tiling.plan_tiles(32, 32, depth=1, tile=16, halo=0)
    canvas = np.zeros((32, 32, 1), np.float32)
    canvas[:16, :16] = 1.0  # one loud tile
    canvas[16:, 16:] = 0.01  # one quiet tile, two empty
    ks = adaptive.classify_tiles(canvas, plan)
    assert ks[0] == 0
    assert ks[3] == adaptive.budget_class(0.01)
    assert ks[1] == ks[2] == adaptive.MAX_CLASS


# ------------------------------------------------------------- the engine


def test_engine_float_matches_whole_image():
    """Acceptance: serving a non-square, non-multiple-of-tile image through
    the micro-batching engine equals the whole-image forward."""
    cfg, params = _net(2)
    images = [
        np.asarray(jax.random.normal(jax.random.PRNGKey(1), (21, 38, 3))),
        np.asarray(jax.random.normal(jax.random.PRNGKey(2), (16, 20, 3))),
        np.asarray(jax.random.normal(jax.random.PRNGKey(4), (21, 38, 3))),
    ]
    eng = SegEngine(cfg, params, tile=8, batch=4, max_active=2)
    results = eng.run(images)
    assert len(results) == 3
    for image, res in zip(images, results):
        assert res.logits.shape == image.shape[:2] + (cfg.n_classes,)
        np.testing.assert_allclose(
            res.logits, _whole_ref(params, image, cfg), rtol=1e-4, atol=1e-4
        )
        assert res.cycles > 0 and res.ops > 0 and res.gops_per_w > 0


def _flat_background_image(rng, h=48, w=64, c=3):
    img = rng.normal(0.0, 0.01, (h, w, c))
    img[8:24, 10:30] += rng.normal(0.0, 1.0, (16, 20, c))
    return img.astype(np.float32)


def test_engine_adaptive_reduces_cycles_at_same_error():
    """Acceptance: content-adaptive tile budgets cut modeled cycles vs the
    uniform per-layer schedule, without worsening the measured error."""
    _, params = _net(2)
    qcfg = dataclasses.replace(
        _net(2)[0], quant_mode="mma_int8", impl="xla",
        plane_schedule=(6, 6, 6, 5, 5),
    )
    image = _flat_background_image(np.random.default_rng(0))
    kw = dict(tile=16, batch=4)
    res_a = SegEngine(qcfg, params, adaptive=True, **kw).run([image])[0]
    res_u = SegEngine(qcfg, params, adaptive=False, **kw).run([image])[0]
    assert res_a.ops == res_u.ops
    assert res_a.cycles < res_u.cycles
    assert res_a.gops_per_w > res_u.gops_per_w
    assert any(k > 0 for k in res_a.class_counts)
    assert res_u.class_counts == {0: res_u.n_tiles}
    # neither schedule wrecks accuracy relative to the full-8 tiled run
    fcfg = dataclasses.replace(qcfg, plane_schedule=None, planes=8)
    ref = SegEngine(fcfg, params, adaptive=False, **kw).run([image])[0]
    denom = float(np.max(np.abs(ref.logits)))
    err_a = float(np.max(np.abs(res_a.logits - ref.logits))) / denom
    err_u = float(np.max(np.abs(res_u.logits - ref.logits))) / denom
    assert err_a <= err_u + 0.05


def test_engine_zero_halo_edge_padding_mode():
    """The cheap mode: halo=0 with edge-replicate conv padding runs and,
    on smooth content (the case it exists for), leaves far smaller *seam*
    error than a hard zero cut.  Real image borders are excluded — there
    the zero-SAME reference is the thing edge padding deliberately trades
    away — so the comparison isolates the artificial tile boundaries."""
    cfg, params = _net(2)
    yy, xx = np.mgrid[0:48, 0:48].astype(np.float32) / 48.0
    image = np.stack([1.0 + yy, 1.0 + xx, 1.5 + yy * xx], axis=-1)
    want = _whole_ref(params, image, cfg)
    res_edge = SegEngine(
        dataclasses.replace(cfg, pad_mode="edge"), params, tile=8, halo=0
    ).run([image])[0]
    res_zero = SegEngine(cfg, params, tile=8, halo=0).run([image])[0]
    b = tiling.halo_for(cfg.depth, cfg.convs_per_stage)  # interior crop
    interior = (slice(b, -b), slice(b, -b))
    err_edge = float(np.max(np.abs((res_edge.logits - want)[interior])))
    err_zero = float(np.max(np.abs((res_zero.logits - want)[interior])))
    assert err_edge > 0  # approximate by design
    assert err_edge < err_zero


def test_engine_rejects_bad_image():
    cfg, params = _net(1)
    eng = SegEngine(cfg, params, tile=8)
    with pytest.raises(ValueError):
        eng.submit(np.zeros((8, 8, cfg.in_ch + 1), np.float32))
    with pytest.raises(ValueError):
        eng.submit(np.zeros((8, 8), np.float32))
    with pytest.raises(ValueError):
        eng.submit(np.zeros((0, 8, cfg.in_ch), np.float32))


def test_engine_validates_geometry_at_construction():
    """A bad tile must fail fast, not wedge a slot at first admission."""
    cfg, params = _net(2)
    with pytest.raises(ValueError):
        SegEngine(cfg, params, tile=6)  # not a multiple of 2**depth
    with pytest.raises(ValueError):
        SegEngine(cfg, params, tile=8, halo=-4)
    with pytest.raises(ValueError):
        SegEngine(cfg, params, tile=8, batch=0)  # would spin step() forever


# ----------------------------------------------------- satellite guards


def test_unet_schedule_length_validated():
    cfg = unet.UNetConfig(depth=2, convs_per_stage=1, plane_schedule=(8, 8))
    with pytest.raises(ValueError, match=r"5 3x3 convs"):
        cfg.schedule()
    assert unet.UNetConfig(depth=2, convs_per_stage=1,
                           plane_schedule=(8,) * 5).schedule().planes == (8,) * 5


def test_unet_forward_rejects_misaligned_input():
    cfg, params = _net(2)
    with pytest.raises(ValueError, match="divisible"):
        unet.forward(params, jnp.zeros((1, 18, 16, 3)), cfg)


@pytest.mark.parametrize("mode", ["edge", "reflect"])
def test_conv_pad_modes_match_manual_pad(mode):
    from repro.kernels import ops

    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.integers(-128, 128, (2, 6, 7, 3)), jnp.int8)
    w = jnp.asarray(rng.integers(-128, 128, (3, 3, 3, 4)), jnp.int8)
    got = ops.mma_conv2d(x, w, pad=1, pad_mode=mode, impl="xla")
    xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)), mode=mode)
    want = ops.mma_conv2d(xp, w, pad=0, impl="xla")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_conv_pad_mode_validation():
    from repro.kernels import ops

    x = jnp.zeros((1, 4, 4, 2), jnp.int8)
    w = jnp.zeros((3, 3, 2, 2), jnp.int8)
    with pytest.raises(ValueError):
        ops.mma_conv2d(x, w, pad_mode="wrap", impl="xla")


def test_rectangular_conv_layers():
    from repro.core import cycle_model as cm

    sq = cm.unet_conv_layers(32, 3, 8, 2, 1)
    rect = cm.unet_conv_layers((32, 32), 3, 8, 2, 1)
    assert [(l.h, l.w, l.cin, l.cout) for l in sq] == \
        [(l.h, l.w, l.cin, l.cout) for l in rect]
    tall = cm.unet_conv_layers((32, 16), 3, 8, 2, 1)
    assert tall[0].h == 32 and tall[0].w == 16
    assert len(tall) == len(sq)


def test_segserve_bench_smoke(tmp_path):
    """The registered benchmark emits the tracker's JSON datapoint,
    demonstrates the tuned-vs-uniform cycle win, and enforces the
    certificate gate (measured <= cert <= target)."""
    import json

    from benchmarks import segserve as bench

    path = tmp_path / "BENCH_segserve.json"
    rows = bench.run(base=4, image_hw=(80, 64), tile=16, n_calib=1,
                     json_path=str(path))
    assert [r[0] for r in rows] == [
        "segserve/full-8", "segserve/uniform", "segserve/adaptive"
    ]
    data = json.loads(path.read_text())
    by_name = {r["name"]: r for r in data["rows"]}
    assert data["adaptive_speedup_vs_uniform"] > 1.0
    assert by_name["adaptive"]["cycles"] < by_name["uniform"]["cycles"]
    assert by_name["adaptive"]["gops_w"] > by_name["uniform"]["gops_w"]
    assert by_name["full-8"]["rel_err"] == 0.0
    for row in data["rows"]:
        for key in ("cycles", "ops", "time_ms", "gops", "gops_w",
                    "energy_mj", "rel_err"):
            assert key in row
    # the satellite gate: certified next to measured, and it must hold —
    # with a tuned plan the adaptive row actually meets the target
    gate = data["gate"]
    assert gate["holds"]
    assert gate["measured"] <= gate["cert"] <= gate["target"]
    assert by_name["adaptive"]["rel_err"] <= data["target_rel_err"]
    assert by_name["adaptive"]["cert"] == gate["cert"]
    assert data["plan"]["workload"] == "unet"


def test_engine_metered_energy_account():
    """The engine's integer-pJ account: per-tile emissions sum exactly to
    the request's metered energy, full-8 prices every cycle at the full
    plane rate, and adaptive truncation saves superlinearly (cheaper rate
    on top of fewer cycles)."""
    from repro.core import energy_model as em

    _, params = _net(2)
    image = _flat_background_image(np.random.default_rng(3))
    kw = dict(tile=16, batch=4)
    fcfg = dataclasses.replace(
        _net(2)[0], quant_mode="mma_int8", impl="xla", planes=8
    )
    eng = SegEngine(fcfg, params, adaptive=False, **kw)
    tile_pj = 0
    for ev in eng.serve_stream([image]):
        assert isinstance(ev.pj, int) and ev.pj > 0
        tile_pj += ev.pj
        res = ev.request.result
    # emissions close against the request account, integer-exactly
    assert res.pj == tile_pj
    # uniform full-8: metered == cycles x full rate, and the metered
    # figures agree with the analytic flat-power ones by construction
    assert res.pj == res.cycles * em.active_rate_pj(8)
    assert res.metered_mj == pytest.approx(
        em.pj_to_mj(res.cycles * em.PJ_FULL_CYCLE)
    )
    assert res.metered_gops_per_w == pytest.approx(
        1000.0 * res.ops / res.pj
    )
    # adaptive truncation: fewer cycles AND a cheaper per-cycle rate
    qcfg = dataclasses.replace(fcfg, planes=None,
                               plane_schedule=(6, 6, 6, 5, 5))
    res_a = SegEngine(qcfg, params, adaptive=True, **kw).run([image])[0]
    res_u = SegEngine(qcfg, params, adaptive=False, **kw).run([image])[0]
    assert res_a.pj < res_u.pj < res.pj
    assert res_a.metered_gops_per_w > res_u.metered_gops_per_w
    # superlinear: the pJ ratio beats the cycle ratio (rate savings ride
    # on top of the cycle shrink)
    assert res_a.pj * res_u.cycles < res_u.pj * res_a.cycles
