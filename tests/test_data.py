"""Data pipeline: determinism, restart-resume indexing, microbatch reshape,
prefetch, memmap source."""
import numpy as np
import pytest

from repro.data import pipeline as dp


def test_step_indexed_determinism():
    cfg = dp.DataConfig(vocab=1000, seq_len=16, global_batch=4, seed=3)
    a = dp.get_batch(cfg, 7)
    b = dp.get_batch(cfg, 7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = dp.get_batch(cfg, 8)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_tokens_in_vocab():
    cfg = dp.DataConfig(vocab=257, seq_len=64, global_batch=8, seed=0)
    b = dp.get_batch(cfg, 0)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 257
    assert b["tokens"].shape == (8, 65)


def test_microbatch_reshape():
    cfg = dp.DataConfig(vocab=100, seq_len=8, global_batch=8, microbatches=4)
    b = dp.get_batch(cfg, 0)
    assert b["tokens"].shape == (4, 2, 9)


def test_extras():
    cfg = dp.DataConfig(vocab=100, seq_len=8, global_batch=2,
                        extras={"patches": (4, 16)})
    b = dp.get_batch(cfg, 0)
    assert b["patches"].shape == (2, 4, 16)


def test_prefetch_matches_direct():
    cfg = dp.DataConfig(vocab=100, seq_len=8, global_batch=2, seed=5)
    pf = dp.host_prefetch(cfg, start_step=3)
    got = [next(pf) for _ in range(3)]
    pf.close()
    for step, batch in got:
        np.testing.assert_array_equal(batch["tokens"], dp.get_batch(cfg, step)["tokens"])
    assert [s for s, _ in got] == [3, 4, 5]


def test_memmap_source(tmp_path):
    data = np.arange(9 * 40, dtype=np.int32)
    path = tmp_path / "tokens.bin"
    data.tofile(path)
    cfg = dp.DataConfig(vocab=1 << 30, seq_len=8, global_batch=4,
                        source="memmap", path=str(path))
    b = dp.get_batch(cfg, 0)
    assert b["tokens"].shape == (4, 9)
    # rows must be contiguous sample slices
    row = b["tokens"][0]
    assert (np.diff(row) == 1).all()
