"""Sharded serving fabric: RoundClock/FleetLedger primitives, exact
fleet-ledger additivity, deterministic routing, share-safe work stealing,
and replay determinism (pure scheduling — FakeAdapter shards, no model)."""
import pytest
from _hypothesis_compat import given, settings, st
from test_gateway import FakeAdapter

from repro.serve.clock import FleetLedger, RoundClock
from repro.serve.fabric import Fabric
from repro.serve.gateway import Gateway


def mk_shard(*, policy="fair", slots=2, unit=1_000, round_budget=4_000,
             shares=None):
    return Gateway(
        [FakeAdapter("a", slots=slots, unit=unit),
         FakeAdapter("b", slots=slots, unit=unit)],
        policy=policy,
        round_budget=round_budget,
        shares=shares or {"a": 0.5, "b": 0.5},
    )


def mk_fabric(n=3, *, router="p2c", seed=11, steal=True, **shard_kw):
    return Fabric(
        [mk_shard(**shard_kw) for _ in range(n)],
        router=router, seed=seed, steal=steal,
    )


def arrivals_for(costs, *, kind="a", spacing=500, start=0):
    """Open-loop arrival tuples (cycle, kind, payload, kw) for FakeAdapter
    shards; payload is the request's cycle cost."""
    return [
        (start + i * spacing, kind, int(c), dict(qos=kind))
        for i, c in enumerate(costs)
    ]


def drive(fab, arr, *, max_rounds=10_000):
    """Feed arrivals window-by-window (the replay contract) and drain."""
    arr = sorted(arr, key=lambda a: a[0])
    i = 0
    while i < len(arr) or fab.pending():
        assert fab.rounds < max_rounds
        end = fab.clock + fab.round_budget
        due = []
        while i < len(arr) and arr[i][0] < end:
            due.append(arr[i])
            i += 1
        fab.step_round(arrivals=due)


# ------------------------------------------------------ clock primitives


def test_round_clock_accounting():
    clk = RoundClock()
    clk.begin_round()
    clk.record_spent(100)  # admission charge: spent, not worked
    clk.record_work(300, "a")
    clk.record_work(200, "b")
    assert clk.round_spent == 600
    assert clk.round_worked == 500
    assert clk.round_class_worked == {"a": 300, "b": 200}
    clk.idle_to(1_000)  # time flows to the boundary, never banked
    assert clk.round_spent == 1_000
    clk.idle_to(400)  # never backwards
    assert clk.round_spent == 1_000
    clk.end_round(1_000)
    assert clk.cycles == 1_000 and clk.rounds == 1
    clk.begin_round()
    assert clk.round_spent == clk.round_worked == 0
    assert clk.worked_total == 500  # totals survive round resets
    assert clk.class_worked_total == {"a": 300, "b": 200}
    snap = clk.snapshot()
    assert snap["cycles"] == 1_000 and snap["worked_total"] == 500


def test_fleet_ledger_rejects_bad_input():
    with pytest.raises(ValueError):
        FleetLedger(0)
    led = FleetLedger(2)
    with pytest.raises(ValueError):
        led.record_round(0, d_ops=-1, d_worked=0)


def test_fleet_ledger_additivity_detects_drift():
    led = FleetLedger(2)
    clocks = [RoundClock(), RoundClock()]
    for s, (ops, worked) in enumerate([(10, 100), (20, 200)]):
        clocks[s].record_work(worked, "a")
        led.record_round(s, d_ops=ops, d_worked=worked,
                         d_class_worked={"a": worked})
    assert led.additivity([10, 20], clocks)["holds"]
    # one dropped unit on one shard must flip the gate
    led.ops[1] -= 1
    add = led.additivity([10, 20], clocks)
    assert not add["holds"]
    assert add["ledger_total_ops"] == add["direct_total_ops"] - 1


# ---------------------------------------------------- fabric construction


def test_fabric_validates_shards():
    with pytest.raises(ValueError):
        Fabric([])
    with pytest.raises(ValueError):
        Fabric([mk_shard()], router="random")
    with pytest.raises(ValueError):
        Fabric([mk_shard(round_budget=4_000), mk_shard(round_budget=8_000)])
    with pytest.raises(ValueError):  # heterogeneous kinds
        Fabric([
            mk_shard(),
            Gateway([FakeAdapter("a")], round_budget=4_000),
        ])


# --------------------------------------------------- ledger additivity


@given(
    st.lists(st.integers(200, 5_000), min_size=1, max_size=24),
    st.sampled_from(["class", "p2c", "deficit"]),
    st.integers(2, 4),
)
@settings(max_examples=25, deadline=None)
def test_ledger_additivity_exact(costs, router, n_shards):
    """Whatever the traffic, router and shard count: the incrementally
    accumulated fleet ledger equals the direct per-shard sums exactly."""
    fab = mk_fabric(n_shards, router=router)
    arr = arrivals_for(costs[::2], kind="a") + \
        arrivals_for(costs[1::2], kind="b", start=250)
    drive(fab, arr)
    add = fab.additivity()
    assert add["holds"]
    assert add["ledger_total_ops"] == add["direct_total_ops"]
    assert add["ledger_total_worked"] == add["direct_total_worked"]
    # FakeAdapter is 1 op/cycle, so the cross-account identity is exact
    assert add["ledger_total_ops"] == sum(costs)
    assert add["ledger_total_worked"] == sum(costs)
    # every request completed somewhere
    assert sum(1 for g in fab.requests if g.done) == len(costs)


# ------------------------------------------------- routing determinism


@pytest.mark.parametrize("router", ["class", "p2c", "deficit"])
def test_routing_deterministic_under_fixed_seed(router):
    costs = [700, 2_400, 900, 3_100, 500, 1_600, 2_000, 800]

    def one_run():
        fab = mk_fabric(3, router=router, seed=42)
        arr = arrivals_for(costs[:4], kind="a") + \
            arrivals_for(costs[4:], kind="b", start=300)
        drive(fab, arr)
        st_ = fab.stats()
        return (
            st_["dispatched"],
            st_["stolen"],
            [(g.qos, g.arrival, g.finished) for g in fab.requests],
        )

    assert one_run() == one_run()


def test_class_router_pins_classes_to_shards():
    fab = mk_fabric(2, router="class", steal=False)
    arr = arrivals_for([500] * 4, kind="a") + \
        arrivals_for([500] * 4, kind="b", start=100)
    drive(fab, arr)
    # sorted classes round-robin: 'a' -> shard 0, 'b' -> shard 1
    assert all(g.qos == "a" for g in fab.shards[0].requests)
    assert all(g.qos == "b" for g in fab.shards[1].requests)
    assert fab.dispatched == [4, 4]


def test_p2c_seed_changes_routing():
    def dispatch(seed):
        fab = mk_fabric(4, router="p2c", seed=seed, steal=False)
        drive(fab, arrivals_for([400] * 24, kind="a", spacing=100))
        return fab.dispatched

    assert dispatch(1) != dispatch(2)  # different draws
    assert dispatch(1) == dispatch(1)  # same seed, same draws


# ------------------------------------------------------- work stealing


def test_stealing_moves_only_queued_requests_and_preserves_shares():
    """A backlogged donor keeps its admitted work and its per-class
    round-budget shares; only never-admitted queue-tail requests move."""
    fab = mk_fabric(2, router="class", steal=True, slots=1,
                    round_budget=4_000)
    donor, thief = fab.shards
    # everything routes to shard 0 ('a' pinned there); shard 1 idles
    arr = arrivals_for([4_000] * 6, kind="a", spacing=0)
    fab.step_round(arrivals=arr)  # all arrive round 0, donor backlogs
    admitted_donor = [g for g in donor.requests if g.admitted is not None]
    assert admitted_donor  # slot filled on the donor
    for _ in range(40):
        if not fab.pending():
            break
        fab.step_round()
    assert fab.stolen > 0 and fab.stolen_from[0] == fab.stolen
    # stolen requests were never admitted on the donor at export time:
    # every request admitted on the thief was admitted there only
    thief_reqs = [g for g in thief.requests]
    assert thief_reqs  # stealing actually moved work
    assert all(g.done for g in fab.requests)
    # donor's own admitted requests completed on the donor (slot state
    # never migrates)
    assert all(g.done for g in admitted_donor)
    donor_ids = {id(g) for g in donor.requests}
    assert all(id(g) in donor_ids for g in admitted_donor)
    # exact conservation: nothing lost or duplicated by the move
    assert len(fab.requests) == 6
    assert fab.additivity()["holds"]


def test_stealing_never_starves_donor_minority_class():
    """While the donor's majority class backlogs (and gets stolen from),
    the donor's own minority class still receives its declared share —
    stealing must not perturb per-class quanta on the stolen-from shard."""
    shares = {"a": 0.5, "b": 0.5}
    fab = Fabric(
        [
            Gateway(
                [FakeAdapter("a", slots=1, unit=1_000),
                 FakeAdapter("b", slots=1, unit=1_000)],
                policy="fair", round_budget=4_000, shares=shares,
            )
            for _ in range(2)
        ],
        router="class", seed=3, steal=True,
    )
    donor = fab.shards[0]
    # 'a' floods shard 0; a minority 'b' request lands there too (router
    # pins 'b' to shard 1, so submit it directly to the donor's queue)
    flood = arrivals_for([4_000] * 8, kind="a", spacing=0)
    fab.step_round(arrivals=flood)
    minority = donor.submit("b", 2_000, arrival_cycle=donor.clock)
    start_round = donor.rounds
    while fab.pending():
        fab.step_round()
        assert fab.rounds < 200
    assert fab.stolen > 0
    assert minority.done
    # fair-share on the donor: the minority finished within the rounds
    # its 0.5 share guarantees (2000 cycles / (0.5 * 4000) = 1 round of
    # quantum + admission round), not after the 'a' backlog drained
    assert minority.finished_round - start_round <= 2
    assert fab.additivity()["holds"]


# --------------------------------------------------- replay determinism


def test_fabric_replay_determinism_per_class_latencies():
    """Two fabric replays of the same trace give identical per-class
    p50/p99 (the ISSUE's replay-determinism property), via the real
    workload.replay harness on modeled adapters."""
    from repro.configs import get_smoke_config
    from repro.serve.modeled import (
        ModeledLMAdapter,
        ModeledSegAdapter,
        modeled_materializer,
    )
    from repro.workload import arrivals, from_streams
    from repro.workload import replay as replay_mod

    cfg = get_smoke_config("minitron_4b")
    trace = from_streams(
        "fabric_det", 99,
        [
            dict(kind="lm", qos="lm",
                 arrivals=arrivals.poisson(12, mean_interval=60_000,
                                           seed=5, start=1_000),
                 payload=dict(prompt_len=4, max_new=6)),
            dict(kind="seg", qos="seg",
                 arrivals=arrivals.deterministic(3, interval=240_000,
                                                 start=9_000),
                 payload=dict(h=56, w=56)),
        ],
        description="determinism probe",
    )

    def one_replay():
        fab = Fabric(
            [
                Gateway(
                    [ModeledLMAdapter.from_config(cfg, batch=4, max_seq=32),
                     ModeledSegAdapter.from_geometry()],
                    policy="fair", round_budget=100_000,
                    shares={"lm": 0.5, "seg": 0.5},
                )
                for _ in range(3)
            ],
            router="p2c", seed=17,
        )
        mats = {k: modeled_materializer() for k in trace.kinds}
        summary = replay_mod.replay(fab, trace, mats)
        assert fab.additivity()["holds"]
        return {
            q: (pc["completed"], pc["p50_ms"], pc["p99_ms"])
            for q, pc in summary["per_class"].items()
        }

    first, second = one_replay(), one_replay()
    assert first == second
    assert all(v[0] > 0 for v in first.values())  # everything completed


def test_fabric_stats_aggregate_shape():
    fab = mk_fabric(2, router="deficit")
    drive(fab, arrivals_for([1_000, 2_000, 3_000], kind="a"))
    st_ = fab.stats()
    assert st_["n_shards"] == 2
    assert st_["additivity"]["holds"]
    assert st_["total_ops"] == 6_000
    assert len(st_["per_shard"]) == 2
    assert sum(s["ops"] for s in st_["per_shard"]) == 6_000
    assert st_["per_class"]["a"]["completed"] == 3
    assert st_["gops_w"] > 0
