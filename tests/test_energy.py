"""Energy-exact metering (repro.core.energy_model + repro.obs.energy):
integer-pJ per-op costing calibrated to the paper's Table 1 proposed
row, the EnergyMeter event-bus sink with its picojoule-exact ledger
reconciliation (gateway and >=4-shard fabric, property-tested across
seeds x policies and seeds x routers), power-cap observability, the
speculative draft/verify energy split with the accept-time rebate, span
joule attachment, and the energy bench smoke."""
import pytest
from _hypothesis_compat import given, settings, st
from test_slo import mk_deadline_trace, mk_fabric, mk_gateway, replay_once

from repro.core import energy_model as em
from repro.obs import RecordingSink, TeeSink, assemble
from repro.obs.energy import (
    EnergyMeter,
    PowerSpec,
    attach_joules,
    find_meter,
)
from repro.obs.events import NULL_SINK, ShardSink
from repro.obs.slo import FLEET

# ------------------------------------------------------- energy model


def test_rate_goldens():
    """The integer pJ/cycle rate model: static + plane-proportional
    dynamic, full width pinned to the calibrated chip power."""
    assert em.PJ_FULL_CYCLE == em.PJ_STATIC_CYCLE + 8 * em.PJ_PLANE_CYCLE
    assert em.PJ_FULL_CYCLE == 34_973
    assert em.active_rate_pj() == em.PJ_FULL_CYCLE
    assert em.active_rate_pj(8) == em.PJ_FULL_CYCLE
    assert em.active_rate_pj(1) == em.PJ_STATIC_CYCLE + em.PJ_PLANE_CYCLE
    # truncation strictly reduces the rate, one plane at a time
    rates = [em.active_rate_pj(b) for b in range(1, 9)]
    assert rates == sorted(rates) and len(set(rates)) == 8
    with pytest.raises(ValueError):
        em.active_rate_pj(0)
    with pytest.raises(ValueError):
        em.active_rate_pj(9)
    assert isinstance(em.active_pj(7, 3), int)
    assert em.active_pj(7, 3) == 7 * em.active_rate_pj(3)
    assert em.idle_pj(5) == 5 * em.PJ_STATIC_CYCLE


def test_calibration_anchor():
    """Full-8 on the calibrated U-Net reproduces the paper's proposed
    row (GOPS/W and energy) within the cycle-model residual — the
    golden the whole rate model hangs off."""
    c = em.calibration()
    assert isinstance(c["energy_pj"], int)
    assert abs(c["rel_err_gops_w"]) < 0.02, c
    assert abs(c["rel_err_e_mj"]) < 0.02, c
    assert abs(c["power_w"] - c["paper_power_w"]) / c["paper_power_w"] \
        < 1e-3
    assert abs(em.modeled_power_w(8) - em.implied_chip_power_w()) \
        / em.implied_chip_power_w() < 1e-3


def test_metered_gops_per_w_relation():
    """GOPS/W = ops / (E_J * 1e9): time cancels, so a run priced at
    constant full power reproduces the analytic figure exactly."""
    assert em.metered_gops_per_w(100, 0) is None
    assert em.metered_gops_per_w(100, -5) is None
    ops, cycles = 2_000_000, 5_000
    pj = cycles * em.PJ_FULL_CYCLE
    metered = em.metered_gops_per_w(ops, pj)
    from repro.core.cycle_model import FREQ_HZ

    t_s = cycles / FREQ_HZ
    analytic = (ops / t_s / 1e9) / em.modeled_power_w(8)
    assert metered == pytest.approx(analytic, rel=1e-9)


def test_schedule_pj_truncation_strictly_cheaper():
    """A truncated plane schedule costs fewer joules than full width —
    both fewer cycles and a lower per-cycle rate — and the per-layer
    breakdown sums to the schedule total exactly."""
    layers = em.cm.unet_conv_layers(**em.cm.CALIBRATED_UNET)
    full = em.schedule_pj(layers, None)
    tuned = em.schedule_pj(layers, (4,))
    assert isinstance(full, int) and isinstance(tuned, int)
    assert tuned < full
    assert sum(em.schedule_layer_pj(layers, (4,))) == tuned
    assert sum(em.schedule_layer_pj(layers, None)) == full


def test_spec_round_pj_closure():
    """The draft/verify split closes exactly: useful + wasted == total,
    waste shrinks monotonically with acceptance, and full acceptance
    wastes nothing."""
    kw = dict(k=4, draft_step_cycles=100, full_step_cycles=400,
              interval_cycles=50, draft_planes=2)
    prev = None
    for a in range(5):
        out = em.spec_round_pj(accepted=a, **kw)
        assert out["useful_pj"] + out["wasted_pj"] == out["total_pj"]
        assert 0 <= out["wasted_pj"] <= out["total_pj"]
        if prev is not None:
            assert out["wasted_pj"] < prev
        prev = out["wasted_pj"]
    assert em.spec_round_pj(accepted=4, **kw)["wasted_pj"] == 0
    # no accepted argument: totals only, still integer
    bare = em.spec_round_pj(**kw)
    assert bare["total_pj"] == bare["draft_pj"] + bare["verify_pj"]
    assert "wasted_pj" not in bare


# ------------------------------------------------- meter on a gateway


RATES = {"a": em.active_rate_pj(4), "b": em.active_rate_pj(8)}


def test_meter_single_gateway_reconciles_and_surfaces():
    meter = EnergyMeter(RATES)
    rec = RecordingSink()
    gw = mk_gateway(sink=TeeSink([rec, meter]))
    summary = replay_once(gw, mk_deadline_trace())
    e = summary["energy"]
    assert e["scope"] is None  # unsharded gateway scope
    assert e["total_pj"] == e["active_pj"] + e["idle_pj"]
    assert e["completions"] > 0 and e["rounds"] > 0
    assert set(e["per_class"]) == {"a", "b"}
    assert "metered_gops_w" in e and "analytic_gops_w" in e
    spans = attach_joules(assemble(rec.events), meter)
    r = meter.reconcile(spans)
    assert r["holds"], r["checks"]
    done = [sp for sp in spans if sp.done]
    assert done and all(sp.pj >= 0 for sp in done)
    assert sum(sp.pj for sp in done) == r["spans"]["online_pj"]
    # the Span.joules convenience derives from the attached pJ
    sp = next(sp for sp in done if sp.pj)
    assert sp.joules == pytest.approx(sp.pj * 1e-12)


def test_energy_block_absent_when_unarmed():
    gw = mk_gateway()
    replay_once(gw, mk_deadline_trace())
    assert "energy" not in gw.stats()


def test_find_meter_unwraps_sink_trees():
    meter = EnergyMeter()
    assert find_meter(meter) == (meter, None)
    assert find_meter(NULL_SINK) == (None, None)
    assert find_meter(TeeSink([RecordingSink(), meter])) == (meter, None)
    m, sh = find_meter(ShardSink(meter, 3))
    assert m is meter and sh == 3
    m, sh = find_meter(TeeSink([ShardSink(meter, 1)]))
    assert m is meter and sh == 1


def test_mid_run_arming_counts_untracked_rounds():
    """Arming after traffic started must not invent idle energy for the
    unseen prefix: the first observed round charges its reported spent
    span only and is counted untracked — and the ledger still closes."""
    gw = mk_gateway()
    replay_once(gw, mk_deadline_trace())
    meter = EnergyMeter(RATES)
    gw.set_sink(meter)
    replay_once(gw, mk_deadline_trace(seed=17))
    s = meter.summary(FLEET)
    assert s["untracked_rounds"] >= 1
    assert meter.reconcile()["holds"]


def test_power_spec_validation():
    with pytest.raises(ValueError):
        PowerSpec(watts=0.0)
    with pytest.raises(ValueError):
        PowerSpec(watts=1.0, window=0)
    with pytest.raises(ValueError):
        PowerSpec(watts=1.0, buckets=0)
    d = PowerSpec(watts=2.5).to_dict()
    assert d["watts"] == 2.5 and d["window"] > 0


def test_power_cap_violations_edge_triggered():
    """An absurdly low cap trips on the first charge: violations are
    edge-triggered (transitions into the over state), over-budget
    charges count every charge above the line, and cap events flow to
    the side sink."""
    side = RecordingSink()
    meter = EnergyMeter(RATES, power=PowerSpec(watts=1e-9), sink=side)
    gw = mk_gateway(sink=meter)
    replay_once(gw, mk_deadline_trace())
    s = meter.summary(scope=None)
    p = s["power"]
    assert p["violations"] >= 1
    assert p["over_budget_charges"] >= p["violations"]
    assert p["budget_watts"] == 1e-9
    assert p["peak_watts"] > 0
    assert meter.cap_events and len(meter.cap_events) <= 64
    assert any(ev.etype == "power-cap" for ev in side.events)
    ev = next(ev for ev in side.events if ev.etype == "power-cap")
    assert ev.data["watts"] > ev.data["budget"]


def test_uncapped_meter_tracks_watts_without_violations():
    meter = EnergyMeter(RATES)  # no PowerSpec
    gw = mk_gateway(sink=meter)
    replay_once(gw, mk_deadline_trace())
    p = meter.summary(scope=None)["power"]
    assert p["budget_watts"] is None and p["violations"] == 0
    assert p["watts"] >= 0 and p["peak_watts"] > 0


# ----------------------------------------------------- property tests


@given(st.integers(1, 10_000), st.sampled_from(["fair", "edf", "fifo"]))
@settings(max_examples=12, deadline=None)
def test_meter_reconciles_across_seeds_and_policies(seed, policy):
    """Invariants 1-3 are scheduling-independent: whatever order the
    policy executes work in, the picojoule ledger closes exactly."""
    meter = EnergyMeter(RATES)
    rec = RecordingSink()
    gw = mk_gateway(policy=policy, sink=TeeSink([rec, meter]))
    replay_once(gw, mk_deadline_trace(seed=seed, n_a=10, n_b=6))
    spans = attach_joules(assemble(rec.events), meter)
    r = meter.reconcile(spans)
    assert r["holds"], (policy, seed, r["checks"])


@given(st.integers(1, 10_000), st.sampled_from(["p2c", "deficit", "class"]))
@settings(max_examples=10, deadline=None)
def test_meter_reconciles_on_fabric(seed, router):
    """On a 4-shard fabric the per-shard ledgers must sum to the
    independently-accumulated fleet totals (invariant 1) for every
    router, and the offline span check must close across shards."""
    meter = EnergyMeter(RATES, power=PowerSpec(watts=50.0))
    rec = RecordingSink()
    fab = mk_fabric(4, sink=TeeSink([rec, meter]), seed=seed,
                    router=router)
    replay_once(fab, mk_deadline_trace(seed=seed))
    spans = attach_joules(assemble(rec.events), meter)
    r = meter.reconcile(spans)
    assert r["holds"], (router, seed, r["checks"])
    add = meter.ledger.additivity()
    assert add["holds"]
    assert add["fleet_active_pj"] == add["shard_active_pj"]
    shards = meter.ledger.shard_scopes()
    assert FLEET not in shards and len(shards) >= 1
    # the fleet power view aggregates the per-shard rings
    fleet_p = meter.summary(FLEET)["power"]
    assert fleet_p["budget_watts"] == pytest.approx(50.0 * len(shards))


# ------------------------------------- speculative energy + the rebate


def _spec_gateway(policy="fair"):
    from repro.configs import get_smoke_config
    from repro.serve.gateway import Gateway
    from repro.serve.modeled import ModeledSpecLMAdapter

    cfg = get_smoke_config("minitron_4b")
    return Gateway(
        [ModeledSpecLMAdapter.from_config(cfg, batch=4, max_seq=48,
                                          draft_schedule=(2,), k=4)],
        policy=policy, round_budget=400_000,
        shares={"interactive": 1.0},
    )


def _drive(gw, n=6):
    arrivals = [
        (i * 10_000, "lm", dict(prompt_len=4, max_new=12),
         dict(qos="interactive"))
        for i in range(n)
    ]
    gw.step_round(arrivals=arrivals)
    rounds = 0
    while gw.pending():
        gw.step_round()
        rounds += 1
        assert rounds < 500, "spec gateway did not drain"


def test_spec_energy_split_closes_and_rebate_applies():
    """The speculative account closes (invariant 4) and the accept-time
    rebate reprices draft cycles from the full-digit to the draft-plane
    rate in the *headline* attribution: versus a meter with no draft
    discount on identical traffic, active energy differs by exactly
    draft_cycles x (full - draft) pJ."""
    r8, r2 = em.active_rate_pj(8), em.active_rate_pj(2)
    m_spec = EnergyMeter({"lm": r8}, draft_rates={"lm": r2})
    m_flat = EnergyMeter({"lm": r8})
    for meter in (m_spec, m_flat):
        gw = _spec_gateway()
        gw.set_sink(meter)
        _drive(gw)
        assert meter.reconcile()["holds"]
    sp = m_spec.spec_summary(FLEET)
    assert sp is not None and sp["rounds"] > 0
    assert sp["draft_pj"] == sp["draft_cycles"] * r2
    assert sp["verify_pj"] == sp["verify_cycles"] * r8
    assert sp["useful_pj"] + sp["wasted_pj"] == sp["total_pj"]
    assert 0 < sp["accept_rate"] <= 1.0
    flat_sp = m_flat.spec_summary(FLEET)
    assert flat_sp["draft_pj"] == flat_sp["draft_cycles"] * r8
    # identical traffic, identical cycles — the only delta is the rebate
    a_spec = m_spec.ledger.state(FLEET).active_pj
    a_flat = m_flat.ledger.state(FLEET).active_pj
    assert a_flat - a_spec == sp["draft_cycles"] * (r8 - r2)
    assert a_spec < a_flat


def test_spec_stats_surface_accept_rate():
    meter = EnergyMeter({"lm": em.active_rate_pj(8)},
                        draft_rates={"lm": em.active_rate_pj(2)})
    gw = _spec_gateway()
    gw.set_sink(meter)
    _drive(gw)
    e = gw.stats()["energy"]
    assert e["spec"]["accept_rate"] is not None
    assert e["spec"]["drafted"] >= e["spec"]["accepted"] > 0


# ------------------------------------------------------- bench smoke


def test_energy_bench_smoke(tmp_path):
    """The full bench machinery on a reduced grid: gates run (and
    raise on violation), the payload carries the comparability key and
    calibration block, and every plan row meters strictly positive
    energy."""
    import json

    import benchmarks.energy as be

    path = tmp_path / "BENCH_energy.json"
    rows = be.run(
        json_path=str(path), shard_counts=(2,), policies=("fair",),
        workload=dict(be.WORKLOAD, span=9_600_000),
    )
    assert len(rows) == 3  # one per plan
    payload = json.loads(path.read_text())
    assert payload["bench"] == "energy" and payload["key"]
    assert payload["gate"]["holds"]
    assert payload["gate"]["reconcile"]["holds"]
    assert payload["gate"]["equal_error_energy_wins"]
    assert abs(payload["calibration"]["rel_err_gops_w"]) < 0.02
    for r in payload["rows"]:
        assert r["total_mj"] > 0 and r["metered_gops_w"] > 0
        assert r["completions"] > 0
