"""Shared serving primitives (serve.queue): the slot table and admission
queue both engines — LM decode and tiled segmentation — are built on."""
import pytest
from _hypothesis_compat import given, settings, st

from repro.serve.queue import FifoQueue, SlotTable


def test_slot_table_lifecycle():
    t = SlotTable(2)
    assert t.capacity == 2
    assert not t.any_active()
    assert t.free_index() == 0
    assert t.occupy("a") == 0
    assert t.occupy("b") == 1
    assert t.occupy("c") is None  # full
    assert t.free_index() is None
    assert t.active() == [(0, "a"), (1, "b")]
    assert t[0] == "a"
    assert t.release(0) == "a"
    assert t[0] is None
    assert t.occupy("c") == 0  # lowest free slot is reused
    assert t.active() == [(0, "c"), (1, "b")]


def test_slot_table_errors():
    with pytest.raises(ValueError):
        SlotTable(0)
    t = SlotTable(1)
    with pytest.raises(KeyError):
        t.release(0)


def test_fifo_pump_admits_in_order_until_full():
    q = FifoQueue(["r0", "r1", "r2"])
    t = SlotTable(2)
    admitted = []

    def admit(item):
        idx = t.occupy(item)
        admitted.append((item, idx))
        return idx is not None

    assert q.pump(t, admit) == 2
    assert admitted == [("r0", 0), ("r1", 1)]
    assert len(q) == 1  # r2 still queued
    t.release(0)
    assert q.pump(t, admit) == 1
    assert not q


def test_fifo_pump_stops_on_admit_false():
    q = FifoQueue(["r0", "r1"])
    t = SlotTable(4)
    assert q.pump(t, lambda item: False) == 0
    assert len(q) == 2  # nothing consumed


def test_lm_engine_runs_on_shared_primitives():
    """The refactored LM engine still serves through a full queue cycle
    (fast smoke of what test_system exercises at scale)."""
    import jax
    import numpy as np

    from repro import models
    from repro.configs import get_smoke_config
    from repro.serve.engine import Engine, Request

    cfg = get_smoke_config("minitron_4b")
    mod = models.build(cfg)
    params = mod.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, batch=2, max_seq=24)
    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=3), max_new=4)
        for i in range(3)
    ]
    done = eng.run(reqs)
    assert len(done) == 3
    assert all(len(r.out) == 4 for r in done)
    assert not eng.slots.any_active()


# ------------------------------------------- head-index layout equivalence


@given(st.lists(st.integers(0, 5), min_size=1, max_size=200))
@settings(max_examples=50, deadline=None)
def test_fifo_queue_matches_plain_list_model(ops):
    """Behavioral regression for the O(1)-head-pop layout: a FifoQueue
    driven by an arbitrary push/pop_at/peek sequence stays observationally
    identical to a plain python list (the pre-fix representation),
    including negative indices and IndexError edges."""
    q: FifoQueue[int] = FifoQueue()
    model: list[int] = []
    serial = 0
    for op in ops:
        if op <= 1 or not model:  # push (biased: queues mostly grow)
            q.push(serial)
            model.append(serial)
            serial += 1
        elif op == 2:  # head pop — the hot admission path
            assert q.pop_at(0) == model.pop(0)
        elif op == 3:  # mid-queue pop (policy scans pop by index)
            i = serial % len(model)
            assert q.pop_at(i) == model.pop(i)
        elif op == 4:  # negative peek
            assert q.peek(-1) == model[-1]
            assert q.peek(-len(model)) == model[0]
        else:  # full observational check
            assert len(q) == len(model)
            assert bool(q) == bool(model)
            assert list(q) == model
            if model:
                assert q.peek(0) == model[0]
            with pytest.raises(IndexError):
                q.peek(len(model))
            with pytest.raises(IndexError):
                q.pop_at(-len(model) - 1)
    assert list(q) == model


def test_fifo_queue_head_pops_compact_storage():
    """Many head pops must not pin the popped prefix: after draining a
    long queue the backing list stays proportional to the live span."""
    q: FifoQueue[int] = FifoQueue(range(1_000))
    for i in range(990):
        assert q.pop_at(0) == i
    assert len(q) == 10
    assert list(q) == list(range(990, 1_000))
    # compaction bound: slack never exceeds max(live span, threshold)
    assert len(q._items) <= 2 * max(len(q), FifoQueue._COMPACT_MIN)
