"""Shared serving primitives (serve.queue): the slot table and admission
queue both engines — LM decode and tiled segmentation — are built on."""
import pytest

from repro.serve.queue import FifoQueue, SlotTable


def test_slot_table_lifecycle():
    t = SlotTable(2)
    assert t.capacity == 2
    assert not t.any_active()
    assert t.free_index() == 0
    assert t.occupy("a") == 0
    assert t.occupy("b") == 1
    assert t.occupy("c") is None  # full
    assert t.free_index() is None
    assert t.active() == [(0, "a"), (1, "b")]
    assert t[0] == "a"
    assert t.release(0) == "a"
    assert t[0] is None
    assert t.occupy("c") == 0  # lowest free slot is reused
    assert t.active() == [(0, "c"), (1, "b")]


def test_slot_table_errors():
    with pytest.raises(ValueError):
        SlotTable(0)
    t = SlotTable(1)
    with pytest.raises(KeyError):
        t.release(0)


def test_fifo_pump_admits_in_order_until_full():
    q = FifoQueue(["r0", "r1", "r2"])
    t = SlotTable(2)
    admitted = []

    def admit(item):
        idx = t.occupy(item)
        admitted.append((item, idx))
        return idx is not None

    assert q.pump(t, admit) == 2
    assert admitted == [("r0", 0), ("r1", 1)]
    assert len(q) == 1  # r2 still queued
    t.release(0)
    assert q.pump(t, admit) == 1
    assert not q


def test_fifo_pump_stops_on_admit_false():
    q = FifoQueue(["r0", "r1"])
    t = SlotTable(4)
    assert q.pump(t, lambda item: False) == 0
    assert len(q) == 2  # nothing consumed


def test_lm_engine_runs_on_shared_primitives():
    """The refactored LM engine still serves through a full queue cycle
    (fast smoke of what test_system exercises at scale)."""
    import jax
    import numpy as np

    from repro import models
    from repro.configs import get_smoke_config
    from repro.serve.engine import Engine, Request

    cfg = get_smoke_config("minitron_4b")
    mod = models.build(cfg)
    params = mod.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, batch=2, max_seq=24)
    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=3), max_new=4)
        for i in range(3)
    ]
    done = eng.run(reqs)
    assert len(done) == 3
    assert all(len(r.out) == 4 for r in done)
    assert not eng.slots.any_active()
