"""Pipeline parallelism: PP(2) x DP(4) loss must match the single-device
loss; gradients must flow (subprocess with 8 forced host devices)."""
import subprocess
import sys

SUB = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke_config
from repro.models import build
from repro.parallel import sharding as shd
from repro.parallel.pipeline import pipelined_loss_fn, bubble_fraction

cfg = get_smoke_config("yi_6b").replace(seq_shard=False)
mod = build(cfg)
params = mod.init_params(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 33)), jnp.int32)}

# single-device reference
ref_loss, _ = mod.loss_fn(params, batch, cfg)
ref_loss = float(ref_loss)

mesh = jax.make_mesh((4, 2), ("data", "model"))  # PP=2, DP=4
with shd.use_mesh(mesh):
    loss_fn = lambda p, b: pipelined_loss_fn(p, b, cfg, n_micro=2)[0]
    pp_loss = float(jax.jit(loss_fn)(params, batch))
    # gradients flow through the pipeline (ppermute transpose)
    g = jax.jit(jax.grad(loss_fn))(params, batch)
gn = float(jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree.leaves(g))))
assert abs(pp_loss - ref_loss) / abs(ref_loss) < 2e-2, (pp_loss, ref_loss)
assert np.isfinite(gn) and gn > 0, gn
# first-layer and last-layer block grads must both be nonzero (both stages
# participated in backward)
gb = g["blocks"]["attn"]["wq"]["w"].astype(jnp.float32)
assert float(jnp.abs(gb[0]).max()) > 0 and float(jnp.abs(gb[-1]).max()) > 0
assert abs(bubble_fraction(2, 2) - 1/3) < 1e-9
print("PIPELINE_OK", pp_loss, ref_loss)
"""


def test_pipeline_subprocess():
    r = subprocess.run(
        [sys.executable, "-c", SUB], capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin",
             "HOME": "/root"},
        cwd="/root/repo",
    )
    assert "PIPELINE_OK" in r.stdout, r.stdout + "\n" + r.stderr[-3000:]
