"""Golden lock on the cycle model: the calibrated relation-(2) outputs and
the Table-1 targets they reproduce.  Any refactor of core/cycle_model.py
that silently drifts these numbers (and hence the paper comparison) fails
here, not three PRs later in a benchmark diff."""
import pytest

from repro.core import cycle_model as cm


@pytest.fixture(scope="module")
def layers():
    return cm.unet_conv_layers(**cm.CALIBRATED_UNET)


def test_paper_constants():
    # relation (2) building blocks, exactly as printed (n=8, T_N=32)
    assert cm.p_out() == 21
    assert cm.mma_tile_cycles() == 28
    assert cm.cascaded_tile_cycles() == 34
    assert cm.pipelined_tile_cycles() == 16


def test_table1_proposed_row_as_printed():
    row = cm.PAPER_TABLE1["proposed"]
    assert row["time_ms"] == 53.25
    assert row["gops"] == 52.95
    assert row["gops_w"] == 15.14
    # derived-column consistency: power and energy follow the definitions
    power = row["gops"] / row["gops_w"]
    assert power == pytest.approx(3.497, abs=2e-3)
    assert power * row["time_ms"] == pytest.approx(row["e_mj"], rel=2e-3)


def test_platform_rows_cross_validate_table1_as_printed():
    """Every Table 1 platform, rebuilt as a PlatformRow from its printed
    (time, power, ops) and cross-validated against the printed derived
    columns.  All printed rows imply one shared workload of ~2.82 GOP
    (ops = gops x time), and every row's energy column is consistent
    with its own power — except the msdf row, whose printed 1644.77 mJ
    contradicts the power implied by its own gops/gops_w columns
    (6.99 W x 133.94 ms = 936.7 mJ, a 1.76x discrepancy *in the paper
    as printed*).  That inconsistency is pinned here deliberately: a
    future 'fix' of either number must be a conscious decision."""
    paper_ops = 2_820_000_000
    for name, t in cm.PAPER_TABLE1.items():
        implied_ops = t["gops"] * t["time_ms"] * 1e6
        assert implied_ops == pytest.approx(paper_ops, rel=1.5e-3), name
        power = t["gops"] / t["gops_w"]
        row = cm.PlatformRow(name, t["time_ms"], power, paper_ops)
        assert row.gops == pytest.approx(t["gops"], rel=1.5e-3), name
        assert row.gops_per_w == pytest.approx(t["gops_w"], rel=2e-3), name
        if name == "msdf":
            assert row.energy_mj == pytest.approx(936.7, rel=2e-3)
            assert t["e_mj"] / row.energy_mj == pytest.approx(1.756,
                                                             rel=2e-3)
        else:
            assert row.energy_mj == pytest.approx(t["e_mj"], rel=5e-3), \
                name
    # the slice-efficiency column round-trips where printed
    for name in ("proposed", "bit_parallel", "bit_serial", "msdf"):
        t = cm.PAPER_TABLE1[name]
        slices = int(t["gops"] / (t["aeff"] * 1e-4))
        row = cm.PlatformRow(name, t["time_ms"], t["gops"] / t["gops_w"],
                             paper_ops, slices=slices)
        assert row.gops_per_slice_e4 == pytest.approx(t["aeff"], rel=2e-3)


def test_calibrated_unet_golden(layers):
    """The calibrated config's relation-(2) outputs, locked exactly."""
    assert cm.CALIBRATED_UNET == dict(
        hw=80, in_ch=4, base=48, depth=3, convs_per_stage=1
    )
    assert len(layers) == 7
    assert cm.model_ops(layers) == 2_809_036_800
    # pipelined steady state: the mode that jointly matches Table 1
    cyc = cm.model_cycles(layers, tile_cycles=cm.pipelined_tile_cycles())
    assert cyc == 5_376_000
    t_ms = cyc / cm.FREQ_HZ * 1e3
    gops = cm.model_ops(layers) / (t_ms * 1e-3) / 1e9
    assert t_ms == pytest.approx(53.76, abs=1e-9)
    assert gops == pytest.approx(52.2514, abs=1e-3)
    # within the calibration residuals of Table 1 (53.25 ms, 52.95 GOPS)
    assert abs(t_ms - 53.25) / 53.25 < 0.011
    assert abs(gops - 52.95) / 52.95 < 0.014
    power = cm.PAPER_TABLE1["proposed"]["gops"] / cm.PAPER_TABLE1["proposed"]["gops_w"]
    assert gops / power == pytest.approx(14.9403, abs=1e-3)  # vs 15.14 GOPS/W


def test_relation2_as_printed_golden(layers):
    assert cm.model_cycles(layers) == 9_408_000
    row = cm.proposed_row(layers)
    assert row.time_ms == pytest.approx(94.08, abs=1e-9)
    assert row.gops == pytest.approx(29.858, abs=1e-3)


def test_schedule_cycles_consistency(layers):
    """Dynamic precision reduces relation-(2) linearly in planes
    (pipelined interval = 2b) and uniform-8 equals the static model."""
    full = cm.model_cycles(layers, tile_cycles=cm.pipelined_tile_cycles())
    assert cm.schedule_cycles(layers, [8] * len(layers)) == full
    assert cm.schedule_cycles(layers, [4] * len(layers)) == full // 2
    assert cm.schedule_cycles(layers, [2] * len(layers)) == full // 4
    # mixed schedule: sum of per-layer terms, monotone in every entry
    per = cm.schedule_layer_cycles(layers, [8, 7, 6, 5, 4, 3, 2])
    assert sum(per) == cm.schedule_cycles(layers, [8, 7, 6, 5, 4, 3, 2])
    assert sum(per) < full
    row = cm.schedule_row(layers, [4] * len(layers))
    assert row.time_ms == pytest.approx(26.88, abs=1e-9)
    assert row.gops_per_w == pytest.approx(2 * 14.9403, abs=1e-2)


def test_lm_pricing_golden():
    """LM decode-step pricing, locked.

    Comment trail (PR 5): the original itemization (4 d_model->d_model
    projections + 2 FFN matmuls, no attention products) is kept as the
    default so every pre-PR5 golden below is *unchanged*; the sharper
    estimate adds GQA-correct projection widths, the attention score/value
    products against a ``context``-token cache, and optional MoE routing.
    The gateway's LM adapter now prices with the sharper form (context =
    max_seq, a conservative upper bound), so its admission estimates grew
    accordingly — BENCH_gateway.json was regenerated in the same PR.
    """
    d_model, d_ff = 128, 256
    # default itemization: unchanged from the PR 4 golden
    base = cm.lm_step_cycles(d_model, d_ff, 2)
    specs = cm.lm_block_layers(d_model, d_ff)
    assert len(specs) == 6
    assert base == 2 * sum(
        s.cycles(tile_cycles=cm.pipelined_tile_cycles()) for s in specs
    )
    # GQA widths: minitron-smoke-like 4 heads x 32, 2 kv heads
    gqa = cm.lm_block_layers(d_model, d_ff, n_heads=4, head_dim=32,
                             n_kv_heads=2)
    assert [s.cout for s in gqa[:4]] == [128, 64, 64, 128]
    # attention products appear with context > 0 and price as T*d_model
    # MACs each (score: hd-contraction x n_heads*T outputs; value:
    # T-contraction x n_heads*hd outputs)
    attn = cm.lm_block_layers(d_model, d_ff, n_heads=4, head_dim=32,
                              n_kv_heads=2, context=16)
    assert len(attn) == 8
    score, value = attn[4], attn[5]
    assert (score.cin, score.cout) == (32, 4 * 16)
    assert (value.cin, value.cout) == (16, 4 * 32)
    assert score.macs() == value.macs() == 16 * d_model
    # MoE routing: router matmul + top_k FFN passes instead of one
    moe = cm.lm_block_layers(d_model, d_ff, n_experts=8, top_k=2)
    assert len(moe) == 4 + 1 + 2 * 2
    assert (moe[4].cin, moe[4].cout) == (d_model, 8)
    # sharper pricing strictly exceeds the old estimate at equal geometry
    sharp = cm.lm_step_cycles(d_model, d_ff, 2, n_heads=4, head_dim=32,
                              n_kv_heads=2, context=16)
    assert sharp > 0
    ops_sharp = cm.lm_step_ops(d_model, d_ff, 2, n_heads=4, head_dim=32,
                               n_kv_heads=2, context=16)
    assert ops_sharp > cm.lm_step_ops(d_model, d_ff, 2, n_heads=4,
                                      head_dim=32, n_kv_heads=2)
    # cycles scale with the schedule exactly as the conv pricing does
    assert cm.lm_step_cycles(d_model, d_ff, 2, [4, 4], n_heads=4,
                             head_dim=32, n_kv_heads=2, context=16) \
        == sharp // 2


def test_schedule_as_printed_mode(layers):
    """mode='as_printed' shrinks p_out with the digit count but keeps the
    fixed delays, so savings are sublinear — unlike pipelined mode."""
    full = cm.schedule_cycles(layers, [8] * 7, mode="as_printed")
    half = cm.schedule_cycles(layers, [4] * 7, mode="as_printed")
    assert full == cm.model_cycles(layers)
    assert full > half > full // 2
