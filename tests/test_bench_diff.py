"""The cross-revision bench tracker (scripts/bench_diff.py): frontier
regressions — GOPS/W drops at equal error target, certificate loosening —
must fail the diff; target changes and new benches must not."""
import copy
import importlib.util
import json
import pathlib
import subprocess

import pytest

_SCRIPT = pathlib.Path(__file__).resolve().parent.parent / "scripts" / "bench_diff.py"


def _load():
    spec = importlib.util.spec_from_file_location("bench_diff", _SCRIPT)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


bd = _load()

BASE = dict(
    bench="autotune",
    rows=[
        dict(name="tuned-0.05", target_rel_err=0.05, gops_w=10.0, cert=0.03),
        dict(name="frontier/full-8", target_rel_err=None, gops_w=12.0,
             cert=None),
        dict(name="tuned-0.02", target_rel_err=0.02, gops_w=8.0, cert=0.0),
    ],
)

GATEWAY = dict(
    bench="gateway",
    gate=dict(minority="seg"),
    rows=[
        dict(policy="fair", gops_w=1.2,
             per_class=dict(seg=dict(p99_ms=20.0), lm=dict(p99_ms=40.0))),
    ],
)


def _regressions(entries):
    return [(e["row"], e["metric"]) for e in entries
            if e["status"] == "regression"]


def test_identical_revisions_are_clean():
    entries = bd.diff_file("f", BASE, copy.deepcopy(BASE),
                           gops_w_tol=0.05, cert_tol=0.01)
    assert not _regressions(entries)
    assert all(e["status"] in ("ok", "note") for e in entries)


def test_gops_w_drop_beyond_tolerance_fails():
    new = copy.deepcopy(BASE)
    new["rows"][0]["gops_w"] = 9.0  # -10% at equal target
    assert ("tuned-0.05", "gops_w") in _regressions(
        bd.diff_file("f", BASE, new, gops_w_tol=0.05, cert_tol=0.01)
    )
    new["rows"][0]["gops_w"] = 9.8  # -2%: inside tolerance
    assert not _regressions(
        bd.diff_file("f", BASE, new, gops_w_tol=0.05, cert_tol=0.01)
    )


def test_certificate_loosening_fails():
    new = copy.deepcopy(BASE)
    new["rows"][0]["cert"] = 0.04  # promised bound grew at equal target
    assert ("tuned-0.05", "cert") in _regressions(
        bd.diff_file("f", BASE, new, gops_w_tol=0.05, cert_tol=0.01)
    )


def test_exact_row_growing_a_bound_fails():
    new = copy.deepcopy(BASE)
    new["rows"][2]["cert"] = 1e-3  # was exact (cert 0)
    assert ("tuned-0.02", "cert") in _regressions(
        bd.diff_file("f", BASE, new, gops_w_tol=0.05, cert_tol=0.01)
    )


def test_disappeared_metric_warns():
    """A watched metric vanishing from the bench must not silently narrow
    the gate: it surfaces as a warning entry."""
    new = copy.deepcopy(BASE)
    del new["rows"][0]["gops_w"]
    new["rows"][0]["cert"] = None
    entries = bd.diff_file("f", BASE, new, gops_w_tol=0.05, cert_tol=0.01)
    assert not _regressions(entries)
    warned = {(e["metric"]) for e in entries
              if e["status"] == "warning" and e["row"] == "tuned-0.05"}
    assert warned == {"gops_w", "cert"}


def test_changed_target_is_skipped_not_compared():
    new = copy.deepcopy(BASE)
    new["rows"][0]["target_rel_err"] = 0.04
    new["rows"][0]["gops_w"] = 1.0  # would be a huge drop if compared
    entries = bd.diff_file("f", BASE, new, gops_w_tol=0.05, cert_tol=0.01)
    assert not _regressions(entries)
    assert any(e["status"] == "skipped" and e["row"] == "tuned-0.05"
               for e in entries)


def test_missing_bench_output_fails_and_missing_baseline_passes():
    entries = bd.diff_file("f", BASE, None, gops_w_tol=0.05, cert_tol=0.01)
    assert _regressions(entries)  # the tracker went blind: loud failure
    entries = bd.diff_file("f", None, copy.deepcopy(BASE),
                           gops_w_tol=0.05, cert_tol=0.01)
    assert not _regressions(entries)  # first revision of a new bench


def test_gateway_latency_shift_warns_but_does_not_fail():
    new = copy.deepcopy(GATEWAY)
    new["rows"][0]["per_class"]["seg"]["p99_ms"] = 30.0  # +50%
    entries = bd.diff_file("f", GATEWAY, new, gops_w_tol=0.05, cert_tol=0.01)
    assert not _regressions(entries)
    assert any(e["status"] == "warning" and e["metric"] == "minority_p99_ms"
               for e in entries)
    new["rows"][0]["gops_w"] = 1.0  # but a GOPS/W drop still fails
    assert _regressions(
        bd.diff_file("f", GATEWAY, new, gops_w_tol=0.05, cert_tol=0.01)
    )


GATEWAY_TRACED = dict(
    bench="gateway",
    gate=dict(minority="seg"),
    trace=dict(name="gateway_burst", version=1),
    rows=[
        dict(policy="fair", gops_w=1.2,
             per_class=dict(
                 seg=dict(p99_ms=20.0),
                 interactive=dict(p99_ms=10.0),
             )),
    ],
)


def test_gateway_rows_key_on_trace_schema():
    """A trace-schema bump (or trace rename) is a target change: rows from
    different trace versions must be skipped, never diffed — the satellite
    guard for workload evolution."""
    # old (pre-trace) baseline vs new traced payload: skipped
    entries = bd.diff_file("f", GATEWAY, copy.deepcopy(GATEWAY_TRACED),
                           gops_w_tol=0.05, cert_tol=0.01)
    assert not _regressions(entries)
    assert any(e["status"] == "skipped" for e in entries)
    # same trace: a GOPS/W drop is a real regression again
    new = copy.deepcopy(GATEWAY_TRACED)
    new["rows"][0]["gops_w"] = 0.5
    assert _regressions(
        bd.diff_file("f", GATEWAY_TRACED, new, gops_w_tol=0.05,
                     cert_tol=0.01)
    )
    # version bump: the same drop is skipped
    new["trace"]["version"] = 2
    entries = bd.diff_file("f", GATEWAY_TRACED, new, gops_w_tol=0.05,
                           cert_tol=0.01)
    assert not _regressions(entries)
    assert any(e["status"] == "skipped" for e in entries)


# ------------------------------------------------------------------ ledger


def test_headline_metrics_shapes():
    seg = dict(bench="segserve", target_rel_err=0.05,
               gate=dict(cert=0.03),
               rows=[dict(name="uniform", gops_w=4.0),
                     dict(name="adaptive", gops_w=13.0)])
    hm = bd.headline_metrics(seg)
    assert hm == dict(target=0.05, gops_w=13.0, cert=0.03)
    auto = dict(bench="autotune", headline_target=0.05,
                rows=[dict(name="tuned-0.05", gops_w=12.9, cert=0.03),
                      dict(name="tuned-0.1", gops_w=13.4, cert=0.05)])
    hm = bd.headline_metrics(auto)
    assert hm["target"] == 0.05 and hm["gops_w"] == 12.9
    hm = bd.headline_metrics(GATEWAY_TRACED)
    assert hm["target"] == "gateway_burst@v1"
    assert hm["interactive_p99_ms"] == 10.0


def _write_benches(tmp_path, gops_w):
    p = tmp_path / "BENCH_gateway.json"
    payload = copy.deepcopy(GATEWAY_TRACED)
    payload["rows"][0]["gops_w"] = gops_w
    p.write_text(json.dumps(payload))
    return [str(p)]


def test_ledger_appends_replaces_and_trend_checks(tmp_path, monkeypatch):
    ledger = str(tmp_path / "LEDGER.jsonl")
    files = _write_benches(tmp_path, 2.0)
    entries = bd.update_ledger(ledger, files, gops_w_tol=0.05)
    assert [e["status"] for e in entries] == ["note"]  # first datapoint
    assert len(bd.load_ledger(ledger)) == 1
    # idempotent on the same revision: replaced, not duplicated
    bd.update_ledger(ledger, files, gops_w_tol=0.05)
    assert len(bd.load_ledger(ledger)) == 1
    # a different revision with a big drop: trend regression
    monkeypatch.setattr(bd, "_git", lambda *a: "deadbeef\n")
    entries = bd.update_ledger(
        _write_benches(tmp_path, 1.0) and ledger,
        _write_benches(tmp_path, 1.0), gops_w_tol=0.05,
    )
    assert [e["status"] for e in entries] == ["regression"]
    assert len(bd.load_ledger(ledger)) == 2
    # a trace/target change on yet another revision: skipped, not failed
    monkeypatch.setattr(bd, "_git", lambda *a: "cafebabe\n")
    files = _write_benches(tmp_path, 0.5)
    payload = json.loads(pathlib.Path(files[0]).read_text())
    payload["trace"]["version"] = 2
    pathlib.Path(files[0]).write_text(json.dumps(payload))
    entries = bd.update_ledger(ledger, files, gops_w_tol=0.05)
    assert [e["status"] for e in entries] == ["skipped"]


@pytest.mark.parametrize("against", ["HEAD"])
def test_cli_runs_clean_against_self(tmp_path, against):
    """End to end through git: the committed baselines diffed against the
    working tree copies of themselves must pass (the CI invocation)."""
    repo = _SCRIPT.parent.parent
    out = tmp_path / "bench_diff.json"
    proc = subprocess.run(
        ["python", str(_SCRIPT), "--base-ref", against,
         "--files", "BENCH_segserve.json", "--out", str(out)],
        cwd=repo, capture_output=True, text=True,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    report = json.loads(out.read_text())
    assert report["holds"] and report["base_ref"] == against


# ------------------------------------------------------- capacity payloads


CAPACITY = dict(
    bench="capacity",
    key="diurnal:1:p100:u100@v1;grid=s[2, 4]xr['deficit']xp['fair']"
        "xpl['uniform8', 'tuned4']",
    rows=[
        dict(label="uniform8/deficit-fair/s2", gops_w=2.0,
             per_class=dict(interactive=dict(p99_ms=90.0))),
        dict(label="uniform8/deficit-fair/s4", gops_w=1.0,
             per_class=dict(interactive=dict(p99_ms=5.0))),
        dict(label="tuned4/deficit-fair/s2", gops_w=2.0,
             per_class=dict(interactive=dict(p99_ms=4.0))),
    ],
    frontier=[
        dict(plan="uniform8", router="deficit", policy="fair",
             min_shards=4, gops_w=1.0),
        dict(plan="tuned4", router="deficit", policy="fair",
             min_shards=2, gops_w=2.0),
    ],
)


def test_capacity_rows_key_on_sweep_key():
    """Capacity rows compare only on the identical grid + workload key:
    a grid change reads as a target change — skipped, never failed."""
    entries = bd.diff_file("f", CAPACITY, copy.deepcopy(CAPACITY),
                           gops_w_tol=0.05, cert_tol=0.01)
    assert not _regressions(entries)
    # a same-key GOPS/W drop fails like any frontier regression
    worse = copy.deepcopy(CAPACITY)
    worse["rows"][1]["gops_w"] = 0.5
    assert ("cap:uniform8/deficit-fair/s4", "gops_w") in _regressions(
        bd.diff_file("f", CAPACITY, worse, gops_w_tol=0.05, cert_tol=0.01)
    )
    # a grid bump changes the key: every row skips
    regrown = copy.deepcopy(worse)
    regrown["key"] = CAPACITY["key"].replace("s[2, 4]", "s[2, 4, 8]")
    entries = bd.diff_file("f", CAPACITY, regrown,
                           gops_w_tol=0.05, cert_tol=0.01)
    assert not _regressions(entries)
    assert any(e["status"] == "skipped" for e in entries)


def test_capacity_headline_is_flagship_frontier_point():
    hm = bd.headline_metrics(CAPACITY)
    assert hm["target"] == CAPACITY["key"]
    assert hm["min_shards"] == 2 and hm["gops_w"] == 2.0
    assert hm["uniform_min_shards"] == 4
    # interactive p99 rides along as the warning-only latency metric
    rows = {rid: m for rid, _, m in bd.comparable_rows(CAPACITY)}
    assert rows["cap:uniform8/deficit-fair/s2"]["minority_p99_ms"] == 90.0


# ----------------------------------------------------- specdecode payloads


SPECDECODE = dict(
    bench="specdecode",
    model=dict(name="minitron_4b", n_layers=8, embed_sharpen=64.0),
    geometry=dict(max_new=24, n_prompts=6),
    plan=dict(spec_planes=[2] * 8, spec_k=4),
    gate=dict(speedup=1.7, accept_rate=0.86, min_speedup=1.5,
              wasted_cycles=1000, holds=True),
)


def test_new_bench_target_skips_with_note_not_keyerror():
    """The satellite bugfix: a brand-new bench target — no
    BENCH_specdecode.json at the merge-base — must read as
    skip-with-a-note, never raise KeyError out of the tracker."""
    entries = bd.diff_file(
        "BENCH_specdecode.json", None, copy.deepcopy(SPECDECODE),
        gops_w_tol=0.05, cert_tol=0.01,
    )
    assert not _regressions(entries)
    assert any(e["status"] == "note" and e["metric"] == "presence"
               for e in entries)


def test_baseline_predating_schema_warns_not_raises():
    """A merge-base payload missing a key the normalizer now indexes is a
    target change (the bench's shape evolved), not a tracker crash."""
    old = dict(bench="specdecode", gate=dict(speedup=1.2))  # no model/plan
    entries = bd.diff_file("f", old, copy.deepcopy(SPECDECODE),
                           gops_w_tol=0.05, cert_tol=0.01)
    assert not _regressions(entries)
    assert any(e["status"] == "warning" and e["metric"] == "schema"
               for e in entries)
    # but the freshly generated payload missing its keys is OUR bug: loud
    entries = bd.diff_file("f", copy.deepcopy(SPECDECODE), old,
                           gops_w_tol=0.05, cert_tol=0.01)
    assert ("*", "schema") in _regressions(entries)


def test_specdecode_speedup_regression_fails_and_target_change_skips():
    entries = bd.diff_file("f", SPECDECODE, copy.deepcopy(SPECDECODE),
                           gops_w_tol=0.05, cert_tol=0.01)
    assert not _regressions(entries)
    worse = copy.deepcopy(SPECDECODE)
    worse["gate"]["speedup"] = 1.5  # -12% at the same operating point
    assert ("spec", "speedup") in _regressions(
        bd.diff_file("f", SPECDECODE, worse, gops_w_tol=0.05,
                     cert_tol=0.01)
    )
    # a different tuned operating point is a different frontier: skipped
    retuned = copy.deepcopy(worse)
    retuned["plan"]["spec_k"] = 2
    entries = bd.diff_file("f", SPECDECODE, retuned, gops_w_tol=0.05,
                           cert_tol=0.01)
    assert not _regressions(entries)
    assert any(e["status"] == "skipped" for e in entries)


def test_specdecode_acceptance_drop_warns_not_fails():
    new = copy.deepcopy(SPECDECODE)
    new["gate"]["accept_rate"] = 0.70  # -19%
    entries = bd.diff_file("f", SPECDECODE, new, gops_w_tol=0.05,
                           cert_tol=0.01)
    assert not _regressions(entries)
    assert any(e["status"] == "warning" and e["metric"] == "accept_rate"
               for e in entries)


def test_specdecode_headline_metrics():
    hm = bd.headline_metrics(SPECDECODE)
    assert hm["speedup"] == 1.7 and hm["accept_rate"] == 0.86
    assert hm["gops_w"] is None and hm["wasted_cycles"] == 1000
    assert "k4@p2" in hm["target"]
    # a schema-less payload yields no headline rather than raising
    assert bd.headline_metrics(dict(bench="specdecode")) is None


def test_specdecode_ledger_trend_checks_speedup(tmp_path, monkeypatch):
    ledger = str(tmp_path / "LEDGER.jsonl")
    p = tmp_path / "BENCH_specdecode.json"
    p.write_text(json.dumps(SPECDECODE))
    entries = bd.update_ledger(ledger, [str(p)], gops_w_tol=0.05)
    assert [e["status"] for e in entries] == ["note"]
    monkeypatch.setattr(bd, "_git", lambda *a: "deadbeef\n")
    worse = copy.deepcopy(SPECDECODE)
    worse["gate"]["speedup"] = 1.4  # -18% on the same operating point
    p.write_text(json.dumps(worse))
    entries = bd.update_ledger(ledger, [str(p)], gops_w_tol=0.05)
    # accept_rate is now a tracked headline column too (unchanged -> ok)
    assert [(e["metric"], e["status"]) for e in entries] == [
        ("ledger:speedup", "regression"), ("ledger:accept_rate", "ok")
    ]


def test_ledger_accept_rate_drop_is_a_regression(tmp_path, monkeypatch):
    """The satellite: accept rate is a tracked BENCH_LEDGER headline
    column — a drop beyond tolerance fails the trend even when the
    speedup headline holds (wasted verify work is an energy regression
    the throughput figure can mask)."""
    ledger = str(tmp_path / "LEDGER.jsonl")
    p = tmp_path / "BENCH_specdecode.json"
    p.write_text(json.dumps(SPECDECODE))
    bd.update_ledger(ledger, [str(p)], gops_w_tol=0.05)
    monkeypatch.setattr(bd, "_git", lambda *a: "deadbeef\n")
    worse = copy.deepcopy(SPECDECODE)
    worse["gate"]["accept_rate"] = 0.70  # -19% at the same speedup
    p.write_text(json.dumps(worse))
    entries = bd.update_ledger(ledger, [str(p)], gops_w_tol=0.05)
    assert [(e["metric"], e["status"]) for e in entries] == [
        ("ledger:speedup", "ok"), ("ledger:accept_rate", "regression")
    ]
