"""Launch-layer units that don't need 512 devices: HLO collective parsing,
analytic HBM model, cell bookkeeping, cycle-model calibration artifacts."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_analysis as ha


SAMPLE_HLO = """
HloModule test
ENTRY main {
  %p0 = bf16[128,256]{1,0} parameter(0)
  %p1 = f32[64]{0} parameter(1)
  %ag = bf16[128,4096]{1,0} all-gather(%p0), replica_groups={}, dimensions={1}
  %ar = f32[64]{0} all-reduce(%p1), to_apply=%add
  %rs = bf16[8,256]{1,0} reduce-scatter(%p0), to_apply=%add
  %a2a = bf16[128,256]{1,0} all-to-all(%p0), dimensions={0}
  %cp.1 = bf16[128,256]{1,0} collective-permute(%p0), source_target_pairs={{0,1}}
  ROOT %dot.5 = f32[128,128]{1,0} dot(%p0, %p0), lhs_contracting_dims={1}, rhs_contracting_dims={1}
}
"""


def test_collective_parser():
    st = ha.collective_stats(SAMPLE_HLO)
    p0 = 128 * 256 * 2
    p1 = 64 * 4
    assert st["bytes_by_kind"]["all-gather"] == p0
    assert st["bytes_by_kind"]["all-reduce"] == p1
    assert st["bytes_by_kind"]["reduce-scatter"] == p0
    assert st["bytes_by_kind"]["all-to-all"] == p0
    assert st["bytes_by_kind"]["collective-permute"] == p0
    assert st["total_count"] == 5


def test_collective_parser_on_real_lowering():
    """Parse a real jitted psum lowering (1 device, degenerate but present
    or absent cleanly)."""
    def f(x):
        return x @ x.T

    text = jax.jit(f).lower(jnp.ones((32, 32))).compile().as_text()
    st = ha.collective_stats(text)
    assert st["total_bytes"] == 0  # no collectives on one device


def test_roofline_terms():
    r = ha.roofline(flops=197e12, bytes_accessed=819e9, coll_bytes=0.0)
    assert r["compute_s"] == pytest.approx(1.0)
    assert r["memory_s"] == pytest.approx(1.0)
    assert r["dominant"] in ("compute", "memory")
    r2 = ha.roofline(1e12, 1e9, 500e9)
    assert r2["dominant"] == "collective"
    assert r2["step_time_lower_bound_s"] == pytest.approx(10.0)


def test_analytic_hbm_decode_is_weights_plus_cache():
    m = ha.analytic_hbm_bytes("decode", w_bytes=4e8, cache_bytes=1e9,
                              logits_bytes=1e6)
    assert m["total"] == pytest.approx(4e8 + 1e9 + 1e6)


def test_analytic_hbm_train_scales_with_microbatches():
    kw = dict(w_bytes=1e9, opt_bytes=6e9, resid_bytes=1e8, n_layers=32,
              logits_bytes=1e9)
    m1 = ha.analytic_hbm_bytes("train", microbatches=1, **kw)
    m4 = ha.analytic_hbm_bytes("train", microbatches=4, **kw)
    assert m4["parts"]["weights"] == 4 * m1["parts"]["weights"]
    assert m4["parts"]["opt"] == m1["parts"]["opt"]  # update happens once


def test_param_specs_shapes():
    from repro.configs import get_smoke_config
    from repro.parallel import param_specs as ps
    from repro.models import build

    cfg = get_smoke_config("rwkv6_3b")
    mod = build(cfg)
    ab = jax.eval_shape(lambda: mod.init_params(jax.random.PRNGKey(0), cfg))
    logical = ps.param_logical(ab, cfg)
    # head (d, vocab) -> vocab-sharded on last dim
    assert logical["head"]["w"] == (None, "vocab")
    # channel-mix wv is row-parallel
    assert logical["blocks"]["channel_mix"]["wv"]["w"][1] == "ffn"
    # norms replicated
    assert all(n is None for n in logical["ln_f"]["scale"])


def test_dryrun_results_complete():
    """All 33 runnable cells x 2 meshes exist with sane content."""
    import json
    from pathlib import Path

    from repro.configs import ARCH_IDS
    from repro.configs.base import cells

    res = Path(__file__).resolve().parents[1] / "results" / "dryrun"
    if not res.exists():
        pytest.skip("dry-run results not generated")
    missing = []
    for arch in ARCH_IDS:
        for shape in cells(arch):
            for mesh in ("16_16", "2_16_16"):
                p = res / f"{arch}__{shape}__{mesh}.json"
                if not p.exists():
                    missing.append(p.name)
                    continue
                r = json.loads(p.read_text())
                assert r["cost"]["flops"] > 0, p.name
                assert r["roofline"]["dominant"] in ("compute", "memory", "collective")
    assert not missing, missing
