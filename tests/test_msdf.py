"""Bit-exactness of the cycle-level MSDF reference model (the paper's
arithmetic): MMA units, online adders, the full KPB — property-tested with
hypothesis against plain integer dot products."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.msdf import (
    DELTA_MMA,
    MMAUnit,
    OnlineSerializer,
    kpb_inner_product,
    sd_to_int,
)


@given(
    st.lists(st.integers(0, 255), min_size=32, max_size=32),
    st.lists(st.integers(-128, 127), min_size=32, max_size=32),
)
@settings(max_examples=150, deadline=None)
def test_mma_unit_bit_exact(acts, weights):
    a = np.array(acts, np.uint8)
    w = np.array(weights, np.int64)
    unit = MMAUnit(w, t_n=32)
    val, cycles = unit.run(a)
    assert val == int(np.dot(a.astype(np.int64), w))
    # relation-2 latency structure: delta + p_out cycles for one inner product
    assert cycles == DELTA_MMA + unit.p_out
    # every digit is a valid SD digit
    assert set(unit.ogf.digits) <= {-1, 0, 1}
    # redundancy invariant: the residual stays representable by the digits
    # not yet emitted (the SD digit set's +-1 correction capacity)
    assert unit.ogf.max_abs_residual < 2 ** (unit.p_out + 1)


@given(st.integers(1, 16), st.data())
@settings(max_examples=50, deadline=None)
def test_mma_unit_other_tn(tn_pow, data):
    tn = max(2, tn_pow)
    a = np.array(data.draw(st.lists(st.integers(0, 255), min_size=tn, max_size=tn)), np.uint8)
    w = np.array(data.draw(st.lists(st.integers(-128, 127), min_size=tn, max_size=tn)), np.int64)
    unit = MMAUnit(w, t_n=tn)
    val, _ = unit.run(a)
    assert val == int(np.dot(a.astype(np.int64), w))


@given(
    st.lists(st.integers(0, 255), min_size=9 * 8, max_size=9 * 8),
    st.lists(st.integers(-128, 127), min_size=9 * 8, max_size=9 * 8),
)
@settings(max_examples=25, deadline=None)
def test_kpb_bit_exact(acts, weights):
    a = np.array(acts, np.uint8).reshape(9, 8)
    w = np.array(weights, np.int64).reshape(9, 8)
    val, cycles = kpb_inner_product(a, w)
    assert val == int(np.sum(a.astype(np.int64) * w))
    # the digit-level pipelined tree must beat sequential unit latencies
    assert cycles < 9 * (DELTA_MMA + 2 * 8 + 4)


def test_kpb_adversarial_extremes():
    for a_v, w_v in [(255, 127), (255, -128), (0, -128), (128, 127)]:
        a = np.full((9, 32), a_v, np.uint8)
        w = np.full((9, 32), w_v, np.int64)
        val, _ = kpb_inner_product(a, w)
        assert val == int(np.sum(a.astype(np.int64) * w))


def test_online_serializer_msdf_order():
    """Digits must appear most-significant-first: prefix reconstructions
    converge monotonically in max error bound."""
    w = np.arange(-16, 16, dtype=np.int64)
    a = np.arange(32, dtype=np.uint8) * 8
    unit = MMAUnit(w, t_n=32)
    val, _ = unit.run(a)
    digits = unit.ogf.digits
    msb = unit.p_out - 1
    errs = []
    for k in range(1, len(digits) + 1):
        partial = sd_to_int(digits[:k], msb)
        errs.append(abs(val - partial))
    # prefix error bounded by remaining digit weights (progressive precision)
    for k, e in enumerate(errs[:-1], start=1):
        assert e < 2 ** (msb - k + 1)
    assert errs[-1] == 0
