"""Per-arch smoke tests (reduced same-family configs): one forward + one
train step on CPU, asserting shapes and no NaNs; plus decode-vs-forward
consistency for every family (the serving path must agree with the training
forward on the same tokens)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.configs.base import SHAPES, cells
from repro.models import build
from repro.optim import adamw
from repro.train import train_step as ts

pytestmark = pytest.mark.slow  # CI runs these in the non-blocking slow job

KEY = jax.random.PRNGKey(0)


def _batch_for(cfg, b=2, s=64):
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s + 1)), jnp.int32)}
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.standard_normal((b, cfg.vlm_patches, cfg.d_model)), jnp.bfloat16
        )
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((b, cfg.enc_seq, cfg.d_model)), jnp.bfloat16
        )
    return batch


def _init(cfg):
    mod = build(cfg)
    if cfg.family == "encdec":
        return mod, mod.init_params(KEY, cfg, max_dec_pos=512)
    return mod, mod.init_params(KEY, cfg)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    spec = {
        "minitron_4b": (32, 3072, 24, 8, 9216, 256000),
        "yi_6b": (32, 4096, 32, 4, 11008, 64000),
        "h2o_danube_3_4b": (24, 3840, 32, 8, 10240, 32000),
        "granite_20b": (52, 6144, 48, 1, 24576, 49152),
        "internvl2_76b": (80, 8192, 64, 8, 28672, 128256),
        "olmoe_1b_7b": (16, 2048, 16, 16, 1024, 50304),
        "dbrx_132b": (40, 6144, 48, 8, 10752, 100352),
        "zamba2_7b": (81, 3584, 32, 32, 14336, 32000),
        "whisper_large_v3": (32, 1280, 20, 20, 5120, 51866),
        "rwkv6_3b": (32, 2560, 40, 40, 8960, 65536),
    }[arch]
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff,
            cfg.vocab) == spec
    if arch == "olmoe_1b_7b":
        assert (cfg.moe.n_experts, cfg.moe.top_k) == (64, 8)
    if arch == "dbrx_132b":
        assert (cfg.moe.n_experts, cfg.moe.top_k) == (16, 4)
    if arch == "zamba2_7b":
        assert cfg.ssm_state == 64
    if arch == "h2o_danube_3_4b":
        assert cfg.swa_window > 0
    if arch == "rwkv6_3b":
        assert cfg.family == "ssm"  # attention-free


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    s = 256 if cfg.family == "hybrid" else 64  # mamba chunk divisibility
    mod, params = _init(cfg)
    state = {"params": params, "opt": adamw.init(params)}
    batch = _batch_for(cfg, b=2, s=s)
    new_state, metrics = jax.jit(lambda st, b: ts.train_step(st, b, cfg))(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    # master (f32) params actually changed (bf16 copies may round to equal at
    # warmup-sized lr)
    delta = jax.tree.leaves(
        jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                     new_state["opt"].master, state["opt"].master)
    )
    assert max(delta) > 0


@pytest.mark.parametrize("arch", ["yi_6b", "olmoe_1b_7b", "whisper_large_v3",
                                   "rwkv6_3b", "zamba2_7b"])
def test_decode_matches_forward(arch):
    """Greedy decode logits must match the teacher-forced forward logits."""
    cfg = get_smoke_config(arch)
    if cfg.moe.n_experts:
        # capacity-dropping legitimately differs between prefill-sized and
        # decode-sized batches; compare the dispatch math dropless.
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    s = 8 if cfg.family != "hybrid" else 8
    mod, params = _init(cfg)
    rng = np.random.default_rng(1)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (2, s)), jnp.int32)

    if cfg.family == "encdec":
        frames = jnp.asarray(rng.standard_normal((2, cfg.enc_seq, cfg.d_model)),
                             jnp.bfloat16)
        memory = mod.encode(params, frames, cfg)
        full = mod.decode(params, tokens, memory, cfg)
        cache = mod.init_cache(cfg, 2, 32)
        outs = []
        for i in range(s):
            lg, cache = mod.decode_step(params, tokens[:, i:i+1], cache, i, cfg,
                                        memory=memory)
            outs.append(lg[:, 0])
    elif cfg.family in ("ssm",):
        full = mod.forward(params, tokens, cfg)
        state = mod.init_state(cfg, 2)
        outs = []
        for i in range(s):
            lg, state = mod.decode_step(params, tokens[:, i:i+1], state, i, cfg)
            outs.append(lg[:, 0])
    elif cfg.family == "hybrid":
        # training path needs chunk-divisible seq; compare on decode-only
        state = mod.init_state(cfg, 2, 32)
        outs = []
        for i in range(s):
            lg, state = mod.decode_step(params, tokens[:, i:i+1], state, i, cfg)
            outs.append(lg[:, 0])
        full = None
    else:
        full = mod.forward(params, tokens, cfg)
        cache = mod.init_cache(cfg, 2, 32)
        outs = []
        for i in range(s):
            lg, cache = mod.decode_step(params, tokens[:, i:i+1], cache, i, cfg)
            outs.append(lg[:, 0])

    dec = jnp.stack(outs, axis=1).astype(jnp.float32)
    assert bool(jnp.all(jnp.isfinite(dec)))
    if full is not None:
        full = full.astype(jnp.float32)
        # bf16 accumulation differences allowed; argmax must agree
        agree = (jnp.argmax(full, -1) == jnp.argmax(dec, -1)).mean()
        assert float(agree) > 0.9, float(agree)


def test_hybrid_decode_matches_chunked_prefill():
    """Mamba2 single-step recurrence must agree with the chunked SSD path."""
    from repro.models import mamba2

    cfg = get_smoke_config("zamba2_7b")
    p = mamba2.init_mamba_block(jax.random.PRNGKey(2), cfg)
    rng = np.random.default_rng(2)
    s = 256  # one chunk
    x = jnp.asarray(rng.standard_normal((1, s, cfg.d_model)) * 0.1, jnp.float32)
    full, _ = mamba2.mamba_forward(p, x.astype(jnp.bfloat16), cfg)
    state = mamba2.init_state(cfg, 1)
    outs = []
    for i in range(s):
        o, state = mamba2.mamba_forward(
            p, x[:, i:i+1].astype(jnp.bfloat16), cfg, state=state
        )
        outs.append(o[:, 0])
    dec = jnp.stack(outs, axis=1).astype(jnp.float32)
    diff = jnp.max(jnp.abs(dec - full.astype(jnp.float32)))
    scale = jnp.max(jnp.abs(full.astype(jnp.float32))) + 1e-6
    assert float(diff / scale) < 0.05, float(diff / scale)


def test_long_context_cells_assignment():
    assert cells("zamba2_7b") == ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    assert cells("rwkv6_3b")[-1] == "long_500k"
    assert cells("h2o_danube_3_4b")[-1] == "long_500k"
    assert "long_500k" not in cells("yi_6b")
    assert "long_500k" not in cells("dbrx_132b")


def test_quantized_forward_close_to_float():
    from repro.configs.base import QuantConfig

    cfg = get_smoke_config("yi_6b")
    mod, params = _init(cfg)
    rng = np.random.default_rng(3)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (2, 32)), jnp.int32)
    f = mod.forward(params, tokens, cfg).astype(jnp.float32)
    qcfg = cfg.replace(quant=QuantConfig(mode="mma_int8", planes=8, impl="xla"))
    q = mod.forward(params, tokens, qcfg).astype(jnp.float32)
    rel = float(jnp.max(jnp.abs(f - q)) / (jnp.max(jnp.abs(f)) + 1e-6))
    assert rel < 0.35, rel  # int8 per-tensor dynamic quant across a 2-layer net
    # progressive precision: fewer planes => larger error, still finite
    q4 = mod.forward(
        params, tokens, cfg.replace(quant=QuantConfig(mode="mma_int8", planes=4))
    ).astype(jnp.float32)
    assert bool(jnp.all(jnp.isfinite(q4)))
