"""Cycle-exact telemetry layer (repro.obs): event bus + sinks, exact
order-statistic percentiles, byte-identical determinism of recorded
streams, null-sink behavioral neutrality, span assembly whose segments
reconcile integer-exactly with the RoundClock/FleetLedger totals, trace
capture round-trips, and the ledger report generator."""
import json

import pytest
from _hypothesis_compat import given, settings, st
from test_gateway import FakeAdapter

from repro.obs import (
    NULL_SINK,
    Event,
    MetricsSink,
    NullSink,
    RecordingSink,
    ShardSink,
    TeeSink,
    assemble,
    breakdown,
    payload_spec,
    reconcile,
)
from repro.obs.capture import CaptureSink
from repro.serve.clock import exact_percentile
from repro.serve.fabric import Fabric
from repro.serve.gateway import Gateway
from repro.workload import arrivals, from_streams
from repro.workload import replay as replay_mod


def _cost_mat(treq, seed, idx):
    return treq.payload["cost"], {}


def mk_gateway(*, policy="fair", sink=None, unit=300, slots=3,
               round_budget=2_000, shares=None):
    return Gateway(
        [FakeAdapter("a", slots=slots, unit=unit),
         FakeAdapter("b", slots=slots, unit=unit)],
        policy=policy, round_budget=round_budget,
        shares=shares or {"a": 0.5, "b": 0.5},
        sink=sink,
    )


def mk_trace(seed=13, n_a=14, n_b=9):
    return from_streams(
        "obs_probe", seed,
        [
            dict(kind="a", qos="a",
                 arrivals=arrivals.poisson(n_a, mean_interval=900,
                                           seed=seed),
                 payload=lambda i: dict(cost=400 + 150 * (i % 5))),
            dict(kind="b", qos="b",
                 arrivals=arrivals.on_off(n_b, seed=seed + 1,
                                          burst_interval=200, on_mean=900,
                                          off_mean=3_000),
                 payload=dict(cost=1_200)),
        ],
    )


def mk_fabric(n=4, *, sink=None, seed=23, router="deficit"):
    return Fabric(
        [mk_gateway() for _ in range(n)],
        router=router, seed=seed, sink=sink,
    )


def replay_once(target, trace, **kw):
    return replay_mod.replay(target, trace, {"a": _cost_mat, "b": _cost_mat},
                             **kw)


# ----------------------------------------------- exact order statistics


def test_exact_percentile_basics():
    assert exact_percentile([], 50) is None
    assert exact_percentile([7], 99) == 7
    # p50 of 4 observations: ceil(0.5*4)=2nd smallest
    assert exact_percentile([4, 1, 3, 2], 50) == 2
    # p99 of 1..100: ceil(0.99*100)=99th smallest
    assert exact_percentile(list(range(1, 101)), 99) == 99
    assert exact_percentile(list(range(1, 101)), 100) == 100
    assert exact_percentile([5, 5, 5], 1) == 5


def test_exact_percentile_edges():
    """Edge cases: empty and single samples at the extreme percentiles,
    pct=0 (the k=0 index clamps to the minimum, never an index error),
    pct=100 (the maximum), and input order independence."""
    assert exact_percentile([], 0) is None
    assert exact_percentile([], 100) is None
    assert exact_percentile([42], 0) == 42
    assert exact_percentile([42], 50) == 42
    assert exact_percentile([42], 100) == 42
    assert exact_percentile([9, 3, 7], 0) == 3
    assert exact_percentile([9, 3, 7], 100) == 9
    vals = [5, 1, 4, 1, 5, 9, 2, 6]
    for pct in (0, 10, 50, 90, 100):
        assert exact_percentile(vals, pct) == \
            exact_percentile(sorted(vals), pct)
        assert exact_percentile(vals, pct) == \
            exact_percentile(list(reversed(vals)), pct)


@given(st.lists(st.integers(0, 10_000), min_size=1, max_size=60),
       st.integers(1, 100))
@settings(max_examples=60, deadline=None)
def test_exact_percentile_is_an_observed_order_statistic(vals, pct):
    """The helper's defining property (vs np.percentile interpolation):
    the result is always an *observed* value, at the smallest order
    statistic covering pct% of the observations."""
    v = exact_percentile(vals, pct)
    assert v in vals
    srt = sorted(vals)
    k = min(max(-(-pct * len(vals) // 100), 1), len(vals))
    assert v == srt[k - 1]
    # at least pct% of observations are <= v
    assert sum(1 for x in vals if x <= v) * 100 >= pct * len(vals) \
        or k == 1


# ------------------------------------------------------- events + sinks


def test_event_canonical_line_is_stable():
    e = Event(12, "exec", dict(rid=3, cycles=700, qos="a"))
    assert e.line() == '[12,"exec",{"cycles":700,"qos":"a","rid":3}]'
    assert json.loads(e.line()) == e.to_obj()
    assert e == Event(12, "exec", dict(qos="a", rid=3, cycles=700))
    assert e != Event(13, "exec", dict(qos="a", rid=3, cycles=700))


def test_sink_zoo():
    assert isinstance(NULL_SINK, NullSink) and not NULL_SINK.enabled
    NULL_SINK.emit(Event(0, "x"))  # no-op, no error

    rec = RecordingSink(etypes=["exec"])
    tee = TeeSink([rec, NULL_SINK])
    met = MetricsSink()
    shard = ShardSink(TeeSink([rec, met]), 2)
    shard.emit(Event(5, "exec", dict(rid=0, cycles=100)))
    shard.emit(Event(6, "grant", dict(qos="a", quantum=50)))
    tee.emit(Event(7, "exec", dict(rid=1, cycles=10)))
    # the filter kept only exec; the shard wrapper tagged its events
    assert [e.etype for e in rec.events] == ["exec", "exec"]
    assert rec.events[0].data["shard"] == 2
    assert "shard" not in rec.events[1].data
    assert len(rec) == 2 and len(rec.lines()) == 2
    assert rec.canonical_bytes().endswith(b"\n")
    assert RecordingSink().canonical_bytes() == b""
    assert met.summary() == dict(
        counts={"exec": 1, "grant": 1}, cycles={"exec": 100}
    )


def test_payload_spec_shapes():
    import numpy as np

    assert payload_spec("seg", dict(h=64, w=32, blob=[1])) == dict(h=64, w=32)
    assert payload_spec("lm", np.zeros(6, np.int32),
                        dict(max_new=4)) == dict(prompt_len=6, max_new=4)
    assert payload_spec("seg", np.zeros((48, 40, 4))) == dict(h=48, w=40)
    assert payload_spec("a", 1_500) == dict(cost=1_500)
    assert payload_spec("lm", object()) == {}


# -------------------------------------------- byte-identical determinism


def test_gateway_event_stream_byte_identical_across_runs():
    tr = mk_trace()

    def stream():
        rec = RecordingSink()
        replay_once(mk_gateway(sink=rec), tr)
        return rec.canonical_bytes()

    a, b = stream(), stream()
    assert a and a == b
    # the stream is substantive: every lifecycle etype is present
    etypes = {json.loads(ln)[1] for ln in a.decode().splitlines()}
    assert {"submit", "admit", "grant", "exec", "complete",
            "round"} <= etypes


def test_fabric_event_stream_byte_identical_across_runs():
    tr = mk_trace(seed=31, n_a=24, n_b=16)

    def stream():
        rec = RecordingSink()
        replay_once(mk_fabric(4, sink=rec), tr)
        return rec.canonical_bytes()

    a, b = stream(), stream()
    assert a and a == b
    lines = [json.loads(ln) for ln in a.decode().splitlines()]
    # every shard-side event is shard-tagged; routing events are present
    etypes = {ln[1] for ln in lines}
    assert "route" in etypes
    shards = {ln[2]["shard"] for ln in lines if "shard" in ln[2]}
    assert shards <= {0, 1, 2, 3} and len(shards) > 1


def test_null_sink_run_statistically_identical():
    """Observation must not change behavior: an uninstrumented replay and
    a fully recorded replay produce the *same* stats() dict."""
    tr = mk_trace(seed=41)
    gw_off = mk_gateway()
    replay_once(gw_off, tr)
    gw_on = mk_gateway(sink=RecordingSink())
    replay_once(gw_on, tr)
    assert gw_off.stats() == gw_on.stats()

    fab_off = mk_fabric(3)
    fab_on = mk_fabric(3, sink=RecordingSink())
    replay_once(fab_off, tr)
    replay_once(fab_on, tr)
    assert fab_off.stats() == fab_on.stats()


# --------------------------------------------------- spans + reconcile


def test_gateway_spans_reconcile_integer_exactly():
    rec = RecordingSink()
    gw = mk_gateway(sink=rec)
    tr = mk_trace(seed=57)
    replay_once(gw, tr)

    spans = assemble(rec.events)
    done = [s for s in spans if s.done]
    assert len(done) == len(tr)
    for s in done:
        # the three segments sum to the latency by construction...
        assert s.queued + s.executing + s.preempted == s.total
        assert s.queued >= 0 and s.executing > 0
        # no forced overdrafts in this traffic (unit << round budget)
        assert not s.overdrafted and s.preempted >= 0
    # ...and the exec segment is the authoritative cycle account
    rc = reconcile(rec.events, [gw.round_clock])
    assert rc["holds"]
    assert rc["total_exec"] == gw.round_clock.worked_total
    assert sum(s.exec_cycles for s in spans) == rc["total_exec"]

    bd = breakdown(spans)
    assert set(bd) == {"a", "b"}
    for qos, entry in bd.items():
        n = entry["n"]
        assert n == sum(1 for s in done if s.qos == qos)
        for key in ("p50", "p99"):
            d = entry[key]
            assert (d["queued_cycles"] + d["exec_cycles"]
                    + d["preempted_cycles"]) == d["total_cycles"]
            # the named request is the exact order statistic
            totals = sorted(s.total for s in done if s.qos == qos)
            assert d["total_cycles"] in totals
        assert entry["p50"]["total_cycles"] <= entry["p99"]["total_cycles"]


def test_fabric_spans_reconcile_per_shard_and_ledger():
    rec = RecordingSink()
    fab = mk_fabric(4, sink=rec)
    tr = mk_trace(seed=61, n_a=30, n_b=20)
    replay_once(fab, tr)

    rc = reconcile(rec.events, [g.round_clock for g in fab.shards],
                   ledger=fab.ledger)
    assert rc["holds"]
    assert rc["exec_cycles"] == rc["worked_total"] == rc["ledger_worked"]
    assert len(rc["exec_cycles"]) == 4
    assert sum(1 for c in rc["exec_cycles"] if c > 0) > 1  # real fan-out
    # FakeAdapter prices 1 op/cycle: total exec == total submitted cost
    assert rc["total_exec"] == sum(r.payload["cost"] for r in tr.requests)

    spans = assemble(rec.events)
    done = [s for s in spans if s.done]
    # conservation through routing + stealing: every request's span
    # completed on exactly one shard
    assert len(done) == len(tr)
    assert all(s.shard in (0, 1, 2, 3) for s in done)
    for s in done:
        assert s.queued + s.executing + s.preempted == s.total


def test_reconcile_detects_a_dropped_cycle():
    rec = RecordingSink()
    gw = mk_gateway(sink=rec)
    replay_once(gw, mk_trace(seed=3, n_a=4, n_b=3))
    assert reconcile(rec.events, [gw.round_clock])["holds"]
    ev = next(e for e in rec.events if e.etype == "exec")
    ev.data["cycles"] -= 1
    assert not reconcile(rec.events, [gw.round_clock])["holds"]


def test_stolen_request_span_assembles_on_the_thief():
    """A stolen request's span is keyed where it completed, and its
    latency runs from the *original* arrival carried by the import
    event."""
    rec = RecordingSink()
    fab = Fabric(
        [mk_gateway(slots=1, unit=1_000, round_budget=4_000)
         for _ in range(2)],
        router="class", seed=3, steal=True, sink=rec,
    )
    # 'a' pins to shard 0 which backlogs; shard 1 idles then steals
    fab.step_round(arrivals=[(0, "a", 4_000, dict(qos="a"))
                             for _ in range(6)])
    for _ in range(60):
        if not fab.pending():
            break
        fab.step_round()
    assert fab.stolen > 0
    etypes = [e.etype for e in rec.events]
    assert "steal" in etypes and "export" in etypes and "import" in etypes
    spans = [s for s in assemble(rec.events) if s.done]
    assert len(spans) == 6
    thief_spans = [s for s in spans if s.shard == 1]
    assert thief_spans  # stolen work completed on the thief
    for s in thief_spans:
        assert s.arrival == 0  # original arrival traveled with the steal
        assert s.queued + s.executing + s.preempted == s.total


# --------------------------------------------------- fleet tile totals


def test_fabric_fleet_tile_totals_are_per_shard_sums():
    fab = mk_fabric(3)
    replay_once(fab, mk_trace(seed=71))
    # synthesize shard-local tile streams (FakeAdapter emits none): the
    # fleet aggregate must equal the direct per-shard sums, dropped
    # events included (bounded deque semantics)
    for i, g in enumerate(fab.shards):
        for t in range(5 * (i + 1)):
            g.tile_events.append(("tile", i, t))
            g._tile_events_seen += 1
    fab.shards[0]._tile_events_seen += 7  # 7 dropped off the deque
    st_ = fab.stats()
    assert st_["tile_events_seen"] == 5 + 10 + 15 + 7
    assert st_["tile_events_kept"] == 5 + 10 + 15
    assert st_["tile_events_dropped"] == 7
    per = st_["per_shard"]
    assert st_["tile_events_seen"] == sum(s["tile_events_seen"] for s in per)
    assert st_["tile_events_kept"] == sum(s["tile_events_kept"] for s in per)
    assert st_["tile_events_dropped"] == sum(
        s["tile_events_dropped"] for s in per
    )


# ------------------------------------------------ capture -> replay


@given(st.lists(st.integers(200, 3_000), min_size=2, max_size=12),
       st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_capture_replay_round_trip_property(costs, seed):
    """Whatever the traffic: capturing a live replay and replaying the
    captured trace reproduces identical per-class latency statistics."""
    tr = from_streams(
        "live", seed,
        [
            dict(kind="a", qos="a",
                 arrivals=[137 * i for i in range(len(costs[::2]))],
                 payload=lambda i: dict(cost=costs[::2][i])),
            dict(kind="b", qos="b",
                 arrivals=[93 + 311 * i for i in range(len(costs[1::2]))],
                 payload=lambda i: dict(cost=costs[1::2][i])),
        ],
    )
    cap = CaptureSink()
    gw = mk_gateway()
    live = replay_once(gw, tr, capture=cap)
    assert len(cap) == len(tr)

    captured = cap.to_trace("live-capture", seed=tr.seed)
    assert captured.meta["source"] == "captured"
    assert captured.meta["captured_requests"] == len(tr)
    # the captured trace carries the original arrivals and specs exactly
    assert [r.arrival_cycle for r in captured.requests] \
        == [r.arrival_cycle for r in tr.requests]
    assert [r.payload for r in captured.requests] \
        == [r.payload for r in tr.requests]

    rep = replay_once(mk_gateway(), captured)
    for qos in ("a", "b"):
        lp, rp = live["per_class"][qos], rep["per_class"][qos]
        assert (lp["completed"], lp["p50_ms"], lp["p99_ms"]) \
            == (rp["completed"], rp["p50_ms"], rp["p99_ms"])
    assert live["overall"] == rep["overall"]


def test_capture_tees_with_an_existing_sink():
    rec = RecordingSink()
    cap = CaptureSink()
    gw = mk_gateway(sink=rec)
    replay_once(gw, mk_trace(seed=5, n_a=5, n_b=4), capture=cap)
    assert len(cap) == 9
    # the prior sink kept recording through the tee
    assert any(e.etype == "complete" for e in rec.events)
    assert isinstance(gw.sink, TeeSink)


def test_capture_relative_deadlines_and_defaults():
    cap = CaptureSink()
    gw = mk_gateway()
    gw.set_sink(cap)
    gw.submit("a", 500, deadline_cycles=10_000, arrival_cycle=0)
    gw.step_round()
    gw.submit("a", 500, arrival_cycle=gw.clock)
    gw.drain()
    tr = cap.to_trace("t", seed=1)
    assert tr.requests[0].deadline_cycles == 10_000  # stored relative
    # no explicit deadline: the gateway's default (deadline_factor x est)
    # is captured faithfully — 4.0 x 500 cycles here
    assert tr.requests[1].deadline_cycles == 2_000


def test_capture_from_modeled_gateway_preserves_engine_specs():
    """End to end on modeled engine adapters: the submit event's spec
    (extracted before lossy preparation) round-trips the workload-schema
    payloads, so the captured trace replays through the same engines."""
    from repro.configs import get_smoke_config
    from repro.serve.modeled import (
        ModeledLMAdapter,
        ModeledSegAdapter,
        modeled_materializer,
    )

    cfg = get_smoke_config("minitron_4b")

    def mk():
        return Gateway(
            [ModeledLMAdapter.from_config(cfg, batch=4, max_seq=32),
             ModeledSegAdapter.from_geometry()],
            policy="fair", round_budget=100_000,
            shares={"lm": 0.5, "seg": 0.5},
        )

    tr = from_streams(
        "modeled_cap", 77,
        [
            dict(kind="lm", qos="lm",
                 arrivals=arrivals.poisson(8, mean_interval=50_000, seed=8),
                 payload=dict(prompt_len=4, max_new=6)),
            dict(kind="seg", qos="seg",
                 arrivals=arrivals.deterministic(2, interval=200_000),
                 payload=dict(h=56, w=56)),
        ],
    )
    mats = {k: modeled_materializer() for k in tr.kinds}
    cap = CaptureSink()
    gw = mk()
    live = replay_mod.replay(gw, tr, mats, capture=cap)
    captured = cap.to_trace("modeled_cap2", seed=tr.seed)
    assert [r.payload for r in captured.requests] \
        == [r.payload for r in tr.requests]
    rep = replay_mod.replay(mk(), captured, mats)
    for qos in ("lm", "seg"):
        assert live["per_class"][qos]["p99_ms"] \
            == rep["per_class"][qos]["p99_ms"]


# ------------------------------------------------------------- report


def _ledger_entry(rev, date, gops_w, p99):
    return dict(
        revision=rev, date=date,
        benches=dict(gateway=dict(
            gops_w=gops_w, target="gateway", cert=None,
            interactive_p99_ms=p99,
        )),
    )


def test_report_trend_and_span_sections(tmp_path):
    from repro.obs.report import build_report

    ledger = tmp_path / "LEDGER.jsonl"
    with open(ledger, "w") as fh:
        for e in [_ledger_entry("aaaa111", "2026-08-01", 4.0, 12.0),
                  _ledger_entry("bbbb222", "2026-08-08", 5.0, 9.0)]:
            fh.write(json.dumps(e) + "\n")

    rec = RecordingSink()
    gw = mk_gateway(sink=rec)
    replay_once(gw, mk_trace(seed=9))
    bench = tmp_path / "BENCH_gateway.json"
    with open(bench, "w") as fh:
        json.dump(dict(
            bench="gateway",
            gate=dict(holds=True),
            spans=dict(
                per_class=breakdown(assemble(rec.events)),
                reconcile=reconcile(rec.events, [gw.round_clock]),
            ),
        ), fh)

    md, payload = build_report(ledger, [str(bench)])
    assert payload["ledger_entries"] == 2
    assert payload["benches"]["gateway"]["gate_holds"] is True
    assert payload["benches"]["gateway"]["spans"]["reconcile"]["holds"]
    assert "### gateway" in md
    assert "+25.00" in md  # 4.0 -> 5.0 GOPS/W delta
    assert "## Span breakdown — gateway" in md
    assert "Ledger reconciliation: holds" in md
    # per-class p50/p99 rows rendered
    assert "| a | " in md and "| b | " in md


def test_report_empty_inputs_degrade(tmp_path):
    from repro.obs.report import build_report

    md, payload = build_report(tmp_path / "missing.jsonl", [])
    assert payload["ledger_entries"] == 0
    assert "trend section empty" in md


def test_report_cli_regenerates_from_artifacts_alone(tmp_path, monkeypatch):
    """scripts/report.py works from committed artifacts with no bench
    re-run — the CI artifact step's contract."""
    import importlib.util
    import pathlib

    root = pathlib.Path(__file__).resolve().parent.parent
    spec = importlib.util.spec_from_file_location(
        "report_cli", root / "scripts" / "report.py"
    )
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)

    monkeypatch.chdir(tmp_path)
    assert mod.main(["--ledger", "nope.jsonl", "--benches"]) == 1
    with open("L.jsonl", "w") as fh:
        fh.write(json.dumps(_ledger_entry("cccc333", "2026-08-09",
                                          3.5, 11.0)) + "\n")
    rc = mod.main(["--ledger", "L.jsonl", "--benches",
                   "--out", "R.md", "--json", "r.json"])
    assert rc == 0
    md = open("R.md").read()
    assert "cccc333" in md and "3.500" in md
    assert json.load(open("r.json"))["ledger_entries"] == 1


def test_bench_diff_headline_gains_span_columns():
    import importlib.util
    import pathlib

    root = pathlib.Path(__file__).resolve().parent.parent
    spec = importlib.util.spec_from_file_location(
        "bench_diff_obs", root / "scripts" / "bench_diff.py"
    )
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    payload = dict(
        bench="gateway",
        rows=[dict(policy="fair", preemptive=True, gops_w=4.0,
                   per_class=dict(interactive=dict(p99_ms=9.0)))],
        gate=dict(preemption=dict(holds=True), holds=True),
        spans=dict(per_class=dict(interactive=dict(
            p99=dict(queued_ms=3.0, exec_ms=1.5, preempted_ms=4.5),
        ))),
    )
    h = mod.headline_metrics(payload)
    assert h["p99_queued_ms"] == 3.0
    assert h["p99_exec_ms"] == 1.5
    assert h["p99_preempted_ms"] == 4.5


def test_report_capacity_frontier_and_slo_tables(tmp_path):
    from repro.obs.report import build_report, frontier_table, slo_tables

    cap = dict(
        bench="capacity",
        attrib_classes=["queued", "preempted", "service", "overdraft"],
        rows=[dict(
            label="uniform8/deficit-fair/s2", gops_w=2.0,
            deadline_misses=5,
            slo=dict(met=False, per_class=dict(interactive=dict(
                burn=dict(cumulative=2.5, windows={}),
                attribution=dict(queued=3, preempted=2, service=0,
                                 overdraft=0),
            ))),
        )],
        frontier=[dict(
            plan="uniform8", router="deficit", policy="fair",
            min_shards=4, gops_w=1.0,
            attribution_shares=dict(interactive=dict(
                queued=0.0, preempted=1.0, service=0.0, overdraft=0.0)),
        )],
        gate=dict(holds=True),
    )
    ft = frontier_table(cap)
    assert "| uniform8 | deficit | fair | 4 | 1.000 |" in ft
    assert "preempted 100%" in ft
    slo = slo_tables(cap)
    assert "**miss**" in slo and "| 3 | 2 | 0 | 0 |" in slo
    # non-capacity payloads render nothing
    assert frontier_table(dict(bench="gateway")) is None
    assert slo_tables(dict(bench="gateway")) is None

    path = tmp_path / "BENCH_capacity.json"
    path.write_text(json.dumps(cap))
    md, payload = build_report(tmp_path / "no_ledger.jsonl", [str(path)])
    assert "## Capacity frontier — cost per SLO" in md
    assert "## SLO burn + miss attribution per grid point" in md
    assert payload["capacity"]["gate_holds"] is True
    assert payload["capacity"]["frontier"] == cap["frontier"]
