"""The unified serving gateway: admission policies over a shared modeled
cycle budget (pure scheduling — synthetic adapters, no model in the loop),
plan invalidation at admission, and the progressive structure-first tile
stream (real SegEngine)."""
import functools

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.serve.gateway import (
    Gateway,
    GatewayRequest,
    StalePlanError,
)


# ------------------------------------------------------ synthetic adapter


class FakeAdapter:
    """A pure cycle-accounting engine: each request is ``cost`` modeled
    cycles of divisible work, served oldest-admitted-first in ``unit``-cycle
    micro-steps — the gateway protocol with the model taken out, so policy
    properties sweep traffic shapes at zero compute.

    ``preemptive=True`` (default): a micro-step that would exceed the
    offered budget is not started (unless ``force``), matching the real
    adapters' chunked path.  ``preemptive=False`` reproduces the PR 4
    atomic loop (runs while ``consumed < budget``, overshooting by up to
    ``unit - 1``)."""

    obs_enabled = False  # armed by Gateway.set_sink, like real adapters
    obs_sink = None

    def __init__(self, kind, *, slots=2, unit=1_000, preemptive=True):
        self.kind = kind
        self.slots = slots
        self.unit = unit
        self.preemptive = preemptive
        self._inflight = {}
        self._remaining = {}
        self.total_ops = 0
        self.fallback_reason = None
        self.work_calls = []  # (budget, consumed, forced) audit trail
        self.exec_log = []  # (rid, qos, cycles, offset) attribution

    def prepare(self, payload, *, rid):
        return int(payload)  # payload is the request's cycle cost

    def free_slots(self):
        return self.slots - len(self._inflight)

    def estimate_cycles(self, payload):
        return int(payload)

    def verify_info(self):
        return None

    def admit(self, greq):
        assert self.free_slots() > 0
        greq.handle = greq
        self._inflight[greq.rid] = greq
        self._remaining[greq.rid] = greq.payload
        return 0

    def has_work(self, qos=None):
        return any(
            qos is None or self._inflight[rid].qos == qos
            for rid in self._remaining
        )

    def work(self, budget, qos=None, force=False, soft_limit=None):
        consumed = 0
        completed = []
        forced = False
        while True:
            rids = [
                rid for rid in self._remaining
                if qos is None or self._inflight[rid].qos == qos
            ]
            if not rids:
                break
            rid = rids[0]
            chunk = min(self.unit, self._remaining[rid])
            if self.preemptive:
                at_soft = soft_limit is not None and consumed >= soft_limit
                if consumed + chunk > budget or at_soft:
                    if not (force and consumed == 0):
                        break
                    forced = True
            elif consumed >= budget:
                break
            force = False
            self._remaining[rid] -= chunk
            consumed += chunk
            self.total_ops += chunk  # 1 op/cycle: GOPS plumbing stays live
            if self.obs_enabled:
                self.exec_log.append(
                    (rid, self._inflight[rid].qos, chunk, consumed)
                )
            if self._remaining[rid] == 0:
                del self._remaining[rid]
                # protocol v3: completion at its own micro-step's offset
                completed.append((self._inflight.pop(rid), consumed))
        self.work_calls.append((budget, consumed, forced))
        return consumed, completed, []


def drain_stats(gw, max_rounds=10_000):
    gw.drain(max_rounds=max_rounds)
    return gw.stats()


# ------------------------------------------------------------- policies


def test_policy_validation():
    with pytest.raises(ValueError):
        Gateway([FakeAdapter("a")], policy="lifo")
    with pytest.raises(ValueError):
        Gateway([], policy="fifo")
    with pytest.raises(ValueError):
        Gateway([FakeAdapter("a")], round_budget=0)
    with pytest.raises(ValueError):
        Gateway([FakeAdapter("a")], on_stale="ignore")
    with pytest.raises(ValueError):
        Gateway(
            [FakeAdapter("a"), FakeAdapter("b")],
            shares={"a": 0.9, "b": 0.9},
        )
    # a silently share-less class would be starvable: submission rejects
    # any scheduling class (kind default or QoS label) not declared in
    # shares — loudly, at the front door
    gw = Gateway([FakeAdapter("a"), FakeAdapter("b")], shares={"a": 1.0})
    with pytest.raises(ValueError, match="undeclared"):
        gw.submit("b", 100)  # kind 'b' unlabeled -> class 'b': undeclared
    with pytest.raises(ValueError, match="undeclared"):
        gw.submit("a", 100, qos="gold")
    gw = Gateway([FakeAdapter("a")], policy="fair_share")  # alias
    assert gw.policy == "fair"
    with pytest.raises(ValueError):
        gw.submit("zzz", 100)


def test_fifo_head_of_line_blocks_minority():
    """The failure mode the gateway exists to fix: under strict FIFO a
    majority burst saturating its engine blocks the queue head, so the
    minority class behind it waits even though *its* engine sits idle.
    Fair-share admits it immediately."""

    def trace(policy):
        a, b = FakeAdapter("a", slots=1), FakeAdapter("b", slots=1)
        gw = Gateway([a, b], policy=policy, round_budget=1_000)
        majors = [gw.submit("a", 1_000) for _ in range(4)]
        minor = gw.submit("b", 1_000)
        gw.drain()
        return majors, minor

    _, minor_fifo = trace("fifo")
    _, minor_fair = trace("fair")
    assert minor_fair.admitted_round == 0
    assert minor_fifo.admitted_round > 0  # HOL-blocked behind the burst
    assert minor_fair.finished < minor_fifo.finished


def test_fair_share_minority_p99_beats_fifo():
    """The bench gate in miniature: same trace, fair-share strictly
    improves the minority class's p99 modeled latency."""

    def p99(policy):
        gw = Gateway(
            [FakeAdapter("a", slots=2), FakeAdapter("b", slots=2)],
            policy=policy, round_budget=2_000,
        )
        for _ in range(8):
            gw.submit("a", 2_000)
        for _ in range(2):
            gw.submit("b", 2_000)
        return drain_stats(gw)["per_class"]["b"]["p99_ms"]

    assert p99("fair") < p99("fifo")


def test_edf_admits_tightest_deadline_first():
    a = FakeAdapter("a", slots=1)
    gw = Gateway([a], policy="edf", round_budget=1_000)
    relaxed = gw.submit("a", 1_000, deadline_cycles=1_000_000)
    tight = gw.submit("a", 1_000, deadline_cycles=500)
    gw.drain()
    assert tight.admitted_round == 0
    assert relaxed.admitted_round > tight.admitted_round
    assert tight.finished < relaxed.finished


def test_work_conserving_when_one_class_idle():
    """An idle class's share is not wasted: a lone busy class drains at
    the full round budget, not at its nominal share."""
    gw = Gateway(
        [FakeAdapter("a", slots=1), FakeAdapter("b", slots=1)],
        policy="fair", round_budget=1_000,
    )
    gw.submit("a", 4_000)
    gw.drain()
    assert gw.rounds == 4  # ceil(4000 / 1000), not ceil(4000 / 500)


def test_stats_account_latency_and_ops():
    gw = Gateway([FakeAdapter("a", slots=1)], policy="fifo",
                 round_budget=1_000)
    r = gw.submit("a", 2_500)
    gw.drain()
    st = gw.stats()
    assert r.done and r.latency_cycles == 2_500  # finished mid round 3
    assert st["per_class"]["a"]["completed"] == 1
    assert st["total_ops"] == 2_500
    assert st["gops_w"] > 0
    assert not gw.pending()


@given(
    st.lists(st.integers(100, 5_000), min_size=1, max_size=12),
    st.lists(st.integers(100, 5_000), min_size=1, max_size=12),
    st.integers(500, 4_000),
)
@settings(max_examples=25, deadline=None)
def test_fair_share_never_starves_a_class(costs_a, costs_b, budget):
    """The no-starvation property: under cycle-budget fair-share every
    admitted request completes within a bounded number of rounds — each
    backlogged class receives at least its quantum (or, when the quantum
    cannot yet afford a micro-step, work-conserving slack keeps the round
    from idling), so every round with pending admitted work serves at
    least one ``unit`` micro-step.  Starved traffic would blow through the
    bound and fail the drain guard."""
    unit = 500
    gw = Gateway(
        [FakeAdapter("a", slots=2, unit=unit),
         FakeAdapter("b", slots=2, unit=unit)],
        policy="fair", round_budget=budget,
    )
    for c in costs_a:
        gw.submit("a", c)
    for c in costs_b:
        gw.submit("b", c)
    # every round serves >= one unit chunk (round_budget >= unit), plus one
    # admission round of slack per request for slot waits
    bound = 2 + len(costs_a) + len(costs_b) + sum(
        -(-c // unit) for c in costs_a + costs_b
    )
    gw.drain(max_rounds=bound)  # raises (fails the property) if exceeded
    assert all(g.done for g in gw.requests)
    assert not gw.pending()
    assert gw.stats()["forced"] == 0  # no step ever outsized a round


# ----------------------------------------------- plan invalidation (real)


@functools.lru_cache(maxsize=1)
def _small_unet():
    import jax

    from repro.models import unet

    cfg = unet.UNetConfig(
        hw=32, in_ch=2, base=4, depth=2, convs_per_stage=1, n_classes=3,
        quant_mode="mma_int8", impl="xla",
    )
    return cfg, unet.init_params(jax.random.PRNGKey(0), cfg)


def _plan_for(params, *, stale: bool):
    """A hand-built v2 plan bound (or mis-bound) to ``params``."""
    from repro.autotune.calibrate import params_fingerprint
    from repro.autotune.plan import TunedPlan

    pfp = "0" * 64 if stale else params_fingerprint(params)
    return TunedPlan(
        workload="unet",
        geometry=dict(depth=2, convs_per_stage=1),
        planes=(6,) * 5,
        target_rel_err=0.1,
        certificate=dict(cert=0.05),
        fingerprint="f" * 64,
        params_fingerprint=pfp,
        tile=28,
        halo=12,
    )


def test_stale_plan_rejected_at_admission_naming_both_fingerprints():
    from repro.autotune.calibrate import params_fingerprint
    from repro.serve.gateway import SegAdapter

    cfg, params = _small_unet()
    plan = _plan_for(params, stale=True)
    gw = Gateway([SegAdapter(cfg, params, plan=plan, batch=2)],
                 policy="fifo", on_stale="reject")
    img = np.zeros((32, 32, 2), np.float32)
    with pytest.raises(StalePlanError) as exc:
        gw.submit("seg", img)
    msg = str(exc.value)
    assert plan.params_fingerprint in msg  # the plan's binding
    assert params_fingerprint(params) in msg  # what is actually served
    assert "stale" in msg
    assert not gw.requests  # nothing entered the system


def test_fresh_plan_admits_and_serves():
    from repro.serve.gateway import SegAdapter

    cfg, params = _small_unet()
    plan = _plan_for(params, stale=False)
    gw = Gateway([SegAdapter(cfg, params, plan=plan, batch=2)],
                 policy="fifo", round_budget=50_000_000)
    r = gw.submit("seg", np.linspace(0, 1, 32 * 32 * 2, dtype=np.float32)
                  .reshape(32, 32, 2))
    gw.drain()
    assert r.done and r.handle.result is not None
    assert gw.stats()["fallbacks"] == {}


def test_stale_plan_falls_back_to_uniform_schedule():
    from repro.serve.gateway import SegAdapter

    cfg, params = _small_unet()
    adapter = SegAdapter(cfg, params, plan=_plan_for(params, stale=True),
                         batch=2)
    gw = Gateway([adapter], policy="fair", on_stale="fallback",
                 round_budget=50_000_000)
    r = gw.submit("seg", np.ones((32, 32, 2), np.float32))
    gw.drain()
    assert r.done
    assert adapter.plan is None  # quarantined
    assert adapter.fallback_reason and "stale" in adapter.fallback_reason
    # the fallback engine runs the certified uniform full-digit schedule
    assert adapter.engine.base_schedule.planes == (8,) * 5
    assert "seg" in gw.stats()["fallbacks"]


# --------------------------------------- progressive tile stream (real)


def _quantized_seg(priority):
    import dataclasses

    import jax

    from repro.models import unet
    from repro.segserve import SegEngine

    cfg = unet.UNetConfig(
        hw=64, in_ch=3, base=4, depth=2, convs_per_stage=1, n_classes=3,
        quant_mode="mma_int8", impl="xla",
    )
    params = unet.init_params(jax.random.PRNGKey(1), cfg)
    sched = unet.schedule_from_params(params, 0.05)
    cfg = dataclasses.replace(cfg, plane_schedule=tuple(sched.planes))
    return SegEngine(cfg, params, tile=16, batch=4, adaptive=True,
                     priority=priority)


@functools.lru_cache(maxsize=1)
def _structured_image():
    from repro.segserve.synth import phantom_image

    return phantom_image(64, 48, 3)


def test_progressive_emission_structure_before_background():
    """The acceptance ordering property: within a request, emitted tile
    budget classes never decrease — every structure tile (low class, full
    amplitude) streams out before any background tile."""
    eng = _quantized_seg(priority=True)
    events = list(eng.serve_stream([np.asarray(_structured_image())]))
    classes = [ev.klass for ev in events]
    assert classes == sorted(classes)
    assert classes[0] == 0 and classes[-1] > 0  # both kinds exercised
    # the stream is complete and consistent
    req = events[-1].request
    assert events[-1].done and req.result is not None
    assert sorted(ev.tile for ev in events) == list(range(req.plan.n_tiles))
    # partial() after completion is the final stitch
    assert np.array_equal(req.partial(), req.result.logits)


def test_progressive_final_stitch_bit_identical_to_non_progressive():
    """Prioritization is scheduling only: the same image served with and
    without structure-first ordering stitches to bit-identical logits."""
    img = np.asarray(_structured_image())
    a = _quantized_seg(priority=True).run([img])[0]
    b = _quantized_seg(priority=False).run([img])[0]
    assert np.array_equal(a.logits, b.logits)
    assert a.cycles == b.cycles  # same tiles at the same class schedules


def test_partial_stitch_grows_monotonically():
    eng = _quantized_seg(priority=True)
    [req] = [eng.submit(np.asarray(_structured_image()))]
    eng.queue.pump(eng.slots, eng._admit)
    seen = 0
    while not req.done:
        events = eng.step()
        assert events
        partial = req.partial() if not req.done else req.result.logits
        written = np.abs(partial).sum(axis=-1) != 0
        seen_now = int(written.sum())
        assert seen_now >= seen  # cores only accumulate
        seen = seen_now


# ------------------------------------------------- mixed real end-to-end


def test_gateway_serves_mixed_real_traffic():
    """Both real engines behind one gateway: the LM burst and a seg image
    co-scheduled, everything completes, tile events stream through."""
    import jax

    from repro import models
    from repro.configs import get_smoke_config
    from repro.serve.gateway import LMAdapter, SegAdapter

    lm_cfg = get_smoke_config("minitron_4b")
    lm_params = models.build(lm_cfg).init_params(jax.random.PRNGKey(0), lm_cfg)
    seg_cfg, seg_params = _small_unet()
    seen = []
    gw = Gateway(
        [
            LMAdapter(lm_cfg, lm_params, batch=2, max_seq=24),
            SegAdapter(seg_cfg, seg_params, batch=2),
        ],
        policy="fair", round_budget=3_000_000, on_event=seen.append,
    )
    rng = np.random.default_rng(0)
    lms = [gw.submit("lm", rng.integers(0, lm_cfg.vocab, size=3), max_new=4)
           for _ in range(3)]
    # a pre-built Request whose rid collides with a gateway rid: completion
    # matching is by handle identity, so it must still finish cleanly
    from repro.serve.engine import Request

    prebuilt = gw.submit(
        "lm", Request(rid=0, prompt=rng.integers(0, lm_cfg.vocab, size=2),
                      max_new=4),
    )
    seg = gw.submit("seg", np.ones((32, 32, 2), np.float32))
    gw.drain(max_rounds=1_000)
    assert all(r.done for r in lms) and seg.done and prebuilt.done
    assert all(len(r.handle.out) == 4 for r in lms)
    assert len(prebuilt.handle.out) == 4
    assert seg.handle.result is not None
    assert seen and seen == list(gw.tile_events)
    st = gw.stats()
    assert st["per_class"]["lm"]["completed"] == 4
    assert st["per_class"]["seg"]["completed"] == 1
    assert st["gops_w"] > 0


# --------------------------------------------- per-completion stamp offsets


def test_per_completion_stamps_within_one_work_call():
    """Protocol v3 regression: two requests finishing inside one work()
    call are stamped at their own micro-step offsets.  Before the fix
    both inherited the call's full consumed — the short request paid the
    long one's latency."""
    ad = FakeAdapter("a", slots=2, unit=1_000)
    gw = Gateway([ad], policy="fair", round_budget=10_000)
    r1 = gw.submit("a", 1_000)
    r2 = gw.submit("a", 3_000)
    gw.step_round()
    assert r1.done and r2.done
    # oldest-first micro-steps: r1 finishes on the first 1000-cycle step,
    # r2 three steps later — distinct stamps, non-decreasing, >= arrival
    assert r1.finished == 1_000
    assert r2.finished == 4_000
    assert r1.arrival <= r1.finished <= r2.finished


def test_legacy_bare_completions_stamp_at_full_consumed():
    """Adapters predating protocol v3 return bare greqs; they keep the
    old semantics — every completion stamped at the call's consumed."""

    class LegacyAdapter(FakeAdapter):
        def work(self, budget, qos=None, force=False, soft_limit=None):
            consumed, completed, events = super().work(
                budget, qos=qos, force=force, soft_limit=soft_limit)
            return consumed, [g for g, _ in completed], events

    ad = LegacyAdapter("a", slots=2, unit=1_000)
    gw = Gateway([ad], policy="fair", round_budget=10_000)
    r1 = gw.submit("a", 1_000)
    r2 = gw.submit("a", 3_000)
    gw.step_round()
    assert r1.done and r2.done
    assert r1.finished == r2.finished == 4_000


def test_decreasing_completion_offsets_rejected():
    """The gateway refuses an adapter whose completion offsets go
    backwards — a stamp that time-travels would corrupt latency stats."""

    class ShuffledAdapter(FakeAdapter):
        def work(self, budget, qos=None, force=False, soft_limit=None):
            consumed, completed, events = super().work(
                budget, qos=qos, force=force, soft_limit=soft_limit)
            return consumed, list(reversed(completed)), events

    ad = ShuffledAdapter("a", slots=2, unit=1_000)
    gw = Gateway([ad], policy="fair", round_budget=10_000)
    gw.submit("a", 1_000)
    gw.submit("a", 3_000)
    with pytest.raises(AssertionError, match="decreasing completion"):
        gw.step_round()


# ------------------------------------------------- bounded event window


class EventfulAdapter(FakeAdapter):
    """FakeAdapter emitting one event per micro-step worked."""

    def work(self, budget, qos=None, force=False, soft_limit=None):
        seq0 = self.total_ops // self.unit
        consumed, completed, _ = super().work(
            budget, qos=qos, force=force, soft_limit=soft_limit)
        events = [dict(seq=seq0 + i) for i in range(consumed // self.unit)]
        return consumed, completed, events


def test_tile_events_bounded_and_on_event_lossless():
    """tile_events keeps only the newest max_kept_events records (the
    unbounded-growth leak), stats() accounts the drop, and the on_event
    callback still sees every event."""
    seen = []
    ad = EventfulAdapter("a", slots=2, unit=1_000)
    gw = Gateway([ad], policy="fair", round_budget=4_000,
                 max_kept_events=3, on_event=seen.append)
    r1 = gw.submit("a", 4_000)
    r2 = gw.submit("a", 4_000)
    gw.drain(max_rounds=50)
    assert r1.done and r2.done
    assert len(seen) == 8  # callback: lossless, 8 micro-steps total
    assert [e["seq"] for e in seen] == list(range(8))
    assert list(gw.tile_events) == seen[-3:]  # window: newest 3 survive
    st = gw.stats()
    assert st["tile_events_seen"] == 8
    assert st["tile_events_kept"] == 3
    assert st["tile_events_dropped"] == 5
    with pytest.raises(ValueError):
        Gateway([FakeAdapter("a")], max_kept_events=0)
