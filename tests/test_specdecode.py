"""Precision-speculative decoding (repro.serve.specdecode + the tune_spec
autotune extension + the v3 plan schema).

The load-bearing property is exact greedy equivalence: for any seed and
any draft plane schedule, the speculative engine's emitted token streams
must be bit-identical to a plain greedy engine's on the same weights and
verify schedule — acceptance is an exact-prefix identity, never a
tolerance.  Alongside it, the cycle model's speculative account must
close integer-exactly (useful + wasted == total), and the serving
adapter's charged rounds must reconcile with the gateway ledger.
"""
import dataclasses
import json

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_smoke_config
from repro.configs.base import QuantConfig
from repro.core import cycle_model as cm

BATCH = 2
MAX_SEQ = 24
VOCAB_SEEDED = {}

# one executable per distinct draft budget — sampled from a pinned pool so
# the property sweep compiles a handful of kernels, not one per example
DRAFT_SCHEDULES = ((1, 1), (2, 2), (4, 4), (2, 6))


def _cfg():
    cfg = get_smoke_config("minitron_4b").replace(n_layers=2)
    return cfg.replace(
        quant=QuantConfig(mode="mma_int8", planes=8,
                          plane_schedule=(8,) * cfg.n_layers)
    )


@pytest.fixture(scope="module")
def model():
    import jax

    from repro import models

    cfg = _cfg()
    params = models.build(cfg).init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prompts(seed, vocab, n=2, length=3):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, size=length).astype(np.int32)
            for _ in range(n)]


def _drain_greedy(cfg, params, prompts, max_new):
    from repro.serve.engine import Engine, Request

    eng = Engine(cfg, params, batch=BATCH, max_seq=MAX_SEQ)
    pending = [Request(rid=i, prompt=p, max_new=max_new)
               for i, p in enumerate(prompts)]
    reqs = list(pending)
    while pending or eng.ready_slots():
        while pending and eng.admit(pending[0]):
            pending.pop(0)
        if not eng.ready_slots():
            break
        eng.step()
    return [list(r.out) for r in reqs]


def _drain_spec(cfg, params, prompts, max_new, *, draft_schedule, k):
    from repro.serve.engine import Request
    from repro.serve.specdecode import SpecEngine

    eng = SpecEngine(cfg, params, batch=BATCH, max_seq=MAX_SEQ,
                     draft_schedule=draft_schedule, k=k)
    pending = [Request(rid=i, prompt=p, max_new=max_new)
               for i, p in enumerate(prompts)]
    reqs = list(pending)
    while pending or eng.ready_slots():
        while pending and eng.admit(pending[0]):
            pending.pop(0)
        if not eng.ready_slots():
            break
        eng.spec_step()
    return [list(r.out) for r in reqs], eng.spec_trace


# --------------------------------------------------------------- identity


@settings(max_examples=6, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=10**6),
    sched=st.sampled_from(DRAFT_SCHEDULES),
    k=st.integers(min_value=1, max_value=3),
)
def test_speculative_decode_is_token_identical_to_greedy(seed, sched, k):
    """For any seed and draft schedule: identical emitted streams, and the
    spec trace's accounting is self-consistent."""
    import jax

    from repro import models

    cfg = _cfg()
    params = models.build(cfg).init_params(jax.random.PRNGKey(0), cfg)
    prompts = _prompts(seed, cfg.vocab)
    greedy = _drain_greedy(cfg, params, prompts, max_new=8)
    spec, trace = _drain_spec(cfg, params, prompts, max_new=8,
                              draft_schedule=sched, k=k)
    assert spec == greedy
    for rec in trace:
        assert 1 <= rec["k"] <= k
        for s in rec["slots"]:
            assert 0 <= s["accepted"] <= rec["k"]
            # emitted = accepted drafts + the verifier's correction,
            # truncated only by the request's max_new remainder
            assert 1 <= s["emitted"] <= s["accepted"] + 1
        assert rec["emitted"] == sum(s["emitted"] for s in rec["slots"])
        assert rec["accepted"] == sum(s["accepted"] for s in rec["slots"])
        assert rec["drafted"] == rec["k"] * len(rec["slots"])


def test_spec_engine_rejects_bad_configs(model):
    from repro.serve.specdecode import SpecEngine

    cfg, params = model
    with pytest.raises(ValueError, match="digit-serial"):
        SpecEngine(cfg.replace(quant=QuantConfig(mode="none")), params,
                   batch=BATCH, max_seq=MAX_SEQ,
                   draft_schedule=(2, 2), k=2)
    with pytest.raises(ValueError, match="covers 1 layers"):
        SpecEngine(cfg, params, batch=BATCH, max_seq=MAX_SEQ,
                   draft_schedule=(2,), k=2)
    with pytest.raises(ValueError, match="outside"):
        SpecEngine(cfg, params, batch=BATCH, max_seq=MAX_SEQ,
                   draft_schedule=(2, 9), k=2)
    with pytest.raises(ValueError, match="k 0 < 1"):
        SpecEngine(cfg, params, batch=BATCH, max_seq=MAX_SEQ,
                   draft_schedule=(2, 2), k=0)


# --------------------------------------------------------- cycle account


@settings(max_examples=25, deadline=None)
@given(
    k=st.integers(min_value=0, max_value=6),
    data=st.data(),
)
def test_spec_cycle_account_closes_integer_exactly(k, data):
    """useful + wasted == total for every acceptance outcome, and the
    total decomposes exactly into k draft steps + one pipelined verify."""
    accepted = data.draw(st.integers(min_value=0, max_value=k))
    draft = (2, 2, 2, 2)
    full = (8, 8, 8, 8)
    acct = cm.lm_spec_step_cycles(
        64, 128, 4, k=k, draft_schedule=draft, schedule=full,
        accepted=accepted,
    )
    assert acct["useful_cycles"] + acct["wasted_cycles"] \
        == acct["total_cycles"]
    assert acct["total_cycles"] == (
        k * acct["draft_step_cycles"] + acct["full_step_cycles"]
        + k * acct["interval_cycles"]
    )
    assert acct["baseline_cycles"] == (accepted + 1) \
        * acct["full_step_cycles"]
    assert acct["wasted_cycles"] == (k - accepted) * (
        acct["draft_step_cycles"] + acct["interval_cycles"]
    )


def test_spec_cycle_account_validates():
    with pytest.raises(ValueError, match="accepted"):
        cm.lm_spec_step_cycles(64, 128, 4, k=2, draft_schedule=(2,) * 4,
                               accepted=3)
    with pytest.raises(ValueError, match="k -1"):
        cm.lm_spec_step_cycles(64, 128, 4, k=-1, draft_schedule=(2,) * 4)


# ------------------------------------------------------- adapter + ledger


def test_spec_adapter_reconciles_with_gateway_ledger(model):
    """Serving through the gateway: every charged speculative round must
    reconcile integer-exactly with RoundClock.worked_total, the lifecycle
    events must be present, and the streams must still equal greedy's."""
    from repro.obs import RecordingSink, reconcile
    from repro.serve import Gateway, SpecLMAdapter

    cfg, params = model
    prompts = _prompts(3, cfg.vocab)
    greedy = _drain_greedy(cfg, params, prompts, max_new=8)

    sink = RecordingSink()
    gw = Gateway(
        [SpecLMAdapter(cfg, params, batch=BATCH, max_seq=MAX_SEQ,
                       draft_schedule=(2, 2), k=2)],
        round_budget=10**9, sink=sink,
    )
    for p in prompts:
        gw.submit("lm", p, max_new=8)
    gw.drain()
    assert [list(g.handle.out) for g in gw.requests] == greedy

    rec = reconcile(sink.events, [gw.round_clock])
    assert rec["holds"], rec
    etypes = {e.etype for e in sink.events}
    assert {"draft", "verify", "accept"} <= etypes
    # the draft+verify event cycles decompose the charged round prices
    # exactly: accepted and rejected speculation both count
    adapter = gw.adapters["lm"]
    spec_cycles = sum(
        e.data["cycles"] for e in sink.events
        if e.etype in ("draft", "verify")
    )
    charged = sum(
        len(r["slots"]) * adapter._spec_slot_cycles(r["k"])
        for r in adapter.engine.spec_trace
    )
    assert spec_cycles == charged
    # and the exec attribution the reconcile gate just verified contains
    # every one of those cycles (prefill accounts for the remainder)
    exec_cycles = sum(e.data["cycles"] for e in sink.events
                      if e.etype == "exec")
    assert spec_cycles <= exec_cycles == rec["total_worked"]


def test_spec_adapter_takes_knobs_from_v3_plan(model):
    from repro.serve import SpecLMAdapter

    cfg, params = model
    plan = _lm_plan(cfg, params, spec_planes=(2, 2), spec_k=3)
    ad = SpecLMAdapter(cfg, params, batch=BATCH, max_seq=MAX_SEQ,
                       plan=plan)
    assert ad.engine.draft_schedule == (2, 2) and ad.engine.k == 3
    with pytest.raises(ValueError, match="draft_schedule and k"):
        SpecLMAdapter(cfg, params, batch=BATCH, max_seq=MAX_SEQ)


# ------------------------------------------------------------ plan schema


def _lm_plan(cfg, params, **spec_kw):
    from repro.autotune.calibrate import params_fingerprint
    from repro.autotune.plan import TunedPlan

    return TunedPlan(
        workload="lm",
        geometry=dict(family=cfg.family, n_layers=cfg.n_layers,
                      d_model=cfg.d_model),
        planes=(8,) * cfg.n_layers,
        target_rel_err=0.05,
        certificate=dict(cert=0.0),
        fingerprint="t" * 64,
        params_fingerprint=params_fingerprint(params),
        **spec_kw,
    )


def test_plan_v3_spec_fields_roundtrip(model):
    from repro.autotune.plan import TunedPlan

    cfg, params = model
    plan = _lm_plan(cfg, params, spec_planes=(2, 2), spec_k=4)
    back = TunedPlan.from_json(json.loads(json.dumps(plan.to_json())))
    assert back.spec_planes == (2, 2) and back.spec_k == 4
    assert back.version == plan.version >= 3
    assert "spec=k4@[2, 2]" in back.describe()


def test_plan_v2_json_loads_with_speculation_off(model):
    """Back-compat: a v2 plan (no spec fields serialized at all) loads
    with both as None — speculation simply stays off."""
    from repro.autotune.plan import TunedPlan

    cfg, params = model
    d = _lm_plan(cfg, params).to_json()
    del d["spec_planes"], d["spec_k"]
    d["version"] = 2
    back = TunedPlan.from_json(d)
    assert back.spec_planes is None and back.spec_k is None
    assert back.version == 2
    assert "spec=" not in back.describe()


def test_plan_spec_field_validation(model):
    cfg, params = model
    with pytest.raises(ValueError, match="set together"):
        _lm_plan(cfg, params, spec_planes=(2, 2))
    with pytest.raises(ValueError, match="set together"):
        _lm_plan(cfg, params, spec_k=2)
    with pytest.raises(ValueError, match="covers 1 layers"):
        _lm_plan(cfg, params, spec_planes=(2,), spec_k=2)
    with pytest.raises(ValueError, match="outside"):
        _lm_plan(cfg, params, spec_planes=(0, 2), spec_k=2)
    with pytest.raises(ValueError, match="spec_k 0 < 1"):
        _lm_plan(cfg, params, spec_planes=(2, 2), spec_k=0)
    with pytest.raises(ValueError, match="lm-only"):
        dataclasses.replace(
            _unet_plan(), spec_planes=(4,) * 5, spec_k=2
        )


def _unet_plan():
    from repro.autotune.plan import TunedPlan

    return TunedPlan(
        workload="unet",
        geometry=dict(depth=2, convs_per_stage=1),
        planes=(4,) * 5,
        target_rel_err=0.05,
        certificate=dict(cert=0.01),
        fingerprint="u" * 64,
        tile=28,
        halo=12,
    )


# ---------------------------------------------------------------- tuning


def test_tune_spec_records_operating_point_on_plan(model):
    """The real search on a 1x1 grid: returns a v3 plan whose spec fields
    and modeled record come from actually running the engine."""
    from repro.autotune import tune_spec

    cfg, params = model
    plan = _lm_plan(cfg, params)
    tuned = tune_spec(
        params, cfg, _prompts(11, cfg.vocab, n=1), plan=plan,
        batch=BATCH, max_seq=MAX_SEQ, max_new=4,
        k_candidates=(2,), plane_candidates=(2,),
    )
    assert tuned.spec_planes == (2, 2) and tuned.spec_k == 2
    assert tuned.version >= 3
    spec = tuned.modeled["spec"]
    assert spec["best"] == dict(planes=2, k=2)
    assert len(spec["grid"]) == 1
    g = spec["grid"][0]
    assert g["emitted"] >= 1 and g["cycles"] > 0
    assert 0 <= g["accepted"] <= g["drafted"]
    # the original plan is untouched (tune_spec extends, not mutates)
    assert plan.spec_planes is None

    with pytest.raises(ValueError, match="extends an LM plan"):
        tune_spec(params, cfg, [], plan=_unet_plan())
