"""Early termination (MSDF progressive precision) — accuracy/arithmetic
trade validated end-to-end, plus hypothesis property tests on the bound."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import bitplane, early_term, mma
from repro.kernels import ref


@given(st.integers(0, 2**31 - 1), st.integers(1, 7))
@settings(max_examples=40, deadline=None)
def test_bound_holds_randomized(seed, planes):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(-128, 128, (4, 64)), jnp.int8)
    w = jnp.asarray(rng.integers(-128, 128, (64, 4)), jnp.int8)
    exact = ref.mma_matmul_ref(x, w)
    approx = ref.mma_matmul_ref(x, w, planes=planes, midpoint=True)
    bound = early_term.truncation_bound(w, planes, midpoint=True)
    assert bool(jnp.all(jnp.abs(exact - approx) <= bound[None, :] + 1))


def test_error_decays_geometrically():
    """Each extra plane should roughly halve the worst-case error."""
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.integers(-128, 128, (256, 16)), jnp.int8)
    bounds = [float(jnp.max(early_term.truncation_bound(w, b, midpoint=False)))
              for b in range(1, 8)]
    for a, b in zip(bounds, bounds[1:]):
        assert b <= a / 2 + 1


def test_planes_sweep_lm_error_monotone():
    """On a small LM, logit error vs the 8-plane reference must shrink
    monotonically as planes increase (progressive precision end-to-end).
    (Top-1 agreement on an *untrained* random net is noise — the trained
    accuracy trade is exercised in examples/train_unet.py instead.)"""
    from repro.configs import get_smoke_config
    from repro.configs.base import QuantConfig
    from repro.models import build

    cfg = get_smoke_config("yi_6b")
    mod = build(cfg)
    params = mod.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jnp.asarray(np.random.default_rng(0).integers(0, cfg.vocab, (2, 24)),
                         jnp.int32)
    ref_logits = mod.forward(
        params, tokens, cfg.replace(quant=QuantConfig(mode="mma_int8", planes=8))
    ).astype(jnp.float32)
    scale = float(jnp.max(jnp.abs(ref_logits))) + 1e-6
    errs = []
    for planes in (4, 5, 6, 7):
        lo = mod.forward(
            params, tokens,
            cfg.replace(quant=QuantConfig(mode="mma_int8", planes=planes)),
        ).astype(jnp.float32)
        errs.append(float(jnp.max(jnp.abs(lo - ref_logits))) / scale)
    assert errs == sorted(errs, reverse=True), errs
    assert errs[-1] < 0.25, errs


def test_choose_planes_monotone_in_target():
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.integers(-128, 128, (512, 64)), jnp.int8)
    picks = [early_term.choose_planes(w, t) for t in (0.3, 0.1, 0.03, 0.01, 0.0)]
    assert picks == sorted(picks)
