"""Fault tolerance: atomic checkpointing, async save, restart-resume,
elastic re-shard, retention GC, and crash-mid-save recovery."""
import json
import os
import shutil
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import Checkpointer


def _state(step=0):
    return {
        "params": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4) + step,
                   "b": jnp.ones((4,), jnp.bfloat16) * step},
        "step": jnp.int32(step),
    }


def test_save_restore_roundtrip(tmp_path):
    ck = Checkpointer(tmp_path)
    st = _state(7)
    ck.save(7, st)
    restored, step = ck.restore(jax.eval_shape(lambda: st))
    assert step == 7
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(st["params"]["w"]))
    assert restored["params"]["b"].dtype == jnp.bfloat16


def test_async_save_overlaps_and_completes(tmp_path):
    ck = Checkpointer(tmp_path)
    for s in (1, 2, 3):
        ck.save_async(s, _state(s))
    ck.wait()
    assert ck.latest_step() == 3


def test_latest_points_to_committed_only(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(5, _state(5))
    # simulate a crash mid-save: a stale .tmp dir must not be visible
    tmp_dir = tmp_path / "step_000000009.tmp"
    tmp_dir.mkdir()
    (tmp_dir / "leaf_00000.npy").write_bytes(b"garbage")
    assert ck.latest_step() == 5
    restored, step = ck.restore(jax.eval_shape(lambda: _state(0)))
    assert step == 5


def test_retention_gc(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    for s in range(6):
        ck.save(s, _state(s))
    dirs = sorted(p.name for p in tmp_path.glob("step_*") if p.is_dir())
    assert len(dirs) == 2
    assert ck.latest_step() == 5


def test_elastic_restore_reshards(tmp_path):
    """Restore under a different device layout (1 device here, but through
    explicit NamedShardings — the mechanism the multi-pod restart uses)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    ck = Checkpointer(tmp_path)
    st = _state(1)
    ck.save(1, st)
    mesh = jax.make_mesh((1,), ("data",))
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), jax.eval_shape(lambda: st))
    restored, _ = ck.restore(jax.eval_shape(lambda: st), shardings=sh)
    assert restored["params"]["w"].sharding == NamedSharding(mesh, P())


def test_trainer_restart_is_bit_deterministic(tmp_path):
    """Kill-and-resume must reproduce the uninterrupted run exactly: the data
    pipeline is step-indexed and the checkpoint carries the full state."""
    from repro.configs import get_smoke_config
    from repro.data.pipeline import DataConfig
    from repro.models import build
    from repro.optim import adamw
    from repro.train import train_step as ts
    from repro.train import trainer

    cfg = get_smoke_config("yi_6b")
    mod = build(cfg)
    params = mod.init_params(jax.random.PRNGKey(0), cfg)
    state0 = {"params": params, "opt": adamw.init(params)}
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4, seed=11)
    step_fn = jax.jit(lambda st, b: ts.train_step(st, b, cfg))
    tc = trainer.TrainerConfig(total_steps=6, ckpt_every=3, log_every=100,
                               ckpt_dir=str(tmp_path / "ck"))

    # uninterrupted run
    final_a, _ = trainer.train(jax.tree.map(jnp.copy, state0), step_fn, dcfg, tc,
                               log=lambda *a: None)

    # interrupted run: stop at 3, resume from checkpoint
    shutil.rmtree(tmp_path / "ck")
    tc_half = trainer.TrainerConfig(total_steps=3, ckpt_every=3, log_every=100,
                                    ckpt_dir=str(tmp_path / "ck"))
    trainer.train(jax.tree.map(jnp.copy, state0), step_fn, dcfg, tc_half,
                  log=lambda *a: None)
    resumed, start = trainer.resume(jax.eval_shape(lambda: state0), tc)
    assert start == 3
    final_b, _ = trainer.train(resumed, step_fn, dcfg, tc, start_step=start,
                               log=lambda *a: None)

    for a, b in zip(jax.tree.leaves(final_a["params"]), jax.tree.leaves(final_b["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_straggler_detection():
    from repro.train.trainer import StepTimer

    t = StepTimer()
    for i in range(10):
        t.record(i, 0.1, factor=3.0)
    assert t.record(10, 0.5, factor=3.0) is True
    assert t.record(11, 0.11, factor=3.0) is False
    assert t.flagged == [10]
