"""KPB conv coverage: kernels.mma_conv2d against two independent oracles —
the pure-jnp masked-matmul reference (kernels/ref.py) and XLA's own
conv_general_dilated — across stride / padding / kernel / channel shapes
(including non-MXU-aligned ones) for all four MMA datapaths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(1234)


def _rand_i8(shape):
    return jnp.asarray(RNG.integers(-128, 128, shape), jnp.int8)


def _xla_conv_int(x, w, stride, pad):
    return jax.lax.conv_general_dilated(
        x.astype(jnp.int32),
        w.astype(jnp.int32),
        (stride, stride),
        ((pad, pad), (pad, pad)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        preferred_element_type=jnp.int32,
    )


CASES = [
    # (n, h, w, cin, cout, k, stride, pad)
    (1, 8, 8, 4, 8, 3, 1, 1),      # the paper's 3x3 SAME shape
    (2, 9, 7, 3, 5, 3, 1, 1),      # non-aligned everything
    (1, 8, 8, 4, 8, 3, 2, 1),      # strided downsample
    (1, 10, 10, 2, 3, 3, 2, 0),    # stride 2, VALID
    (2, 6, 6, 5, 7, 1, 1, 0),      # 1x1 conv (pointwise)
    (1, 12, 12, 3, 4, 5, 2, 2),    # 5x5, stride 2
    (1, 7, 11, 33, 65, 3, 1, 1),   # channel counts off the 32/128 tiles
]


@pytest.mark.parametrize("impl", ["pallas", "xla", "cascade", "int8"])
@pytest.mark.parametrize("case", CASES, ids=lambda c: "x".join(map(str, c)))
def test_conv_all_impls_exact(case, impl):
    n, h, w_, cin, cout, k, stride, pad = case
    x = _rand_i8((n, h, w_, cin))
    w = _rand_i8((k, k, cin, cout))
    kw = dict(interpret=True) if impl == "pallas" else {}
    got = ops.mma_conv2d(x, w, stride=stride, pad=pad, impl=impl, **kw)
    want_ref = ref.mma_conv2d_ref(x, w, stride=stride, pad=pad)
    want_xla = _xla_conv_int(x, w, stride, pad)
    assert got.shape == want_xla.shape
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want_ref))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want_xla))


@pytest.mark.parametrize("impl", ["pallas", "xla", "cascade", "int8"])
@pytest.mark.parametrize("planes", [6, 3, 1])
def test_conv_truncated_all_impls(planes, impl):
    """Plane truncation agrees with the masked-matmul oracle on every
    datapath (the int8 baseline computes it via the data-side identity)."""
    x = _rand_i8((2, 7, 9, 5))
    w = _rand_i8((3, 3, 5, 6))
    kw = dict(interpret=True) if impl == "pallas" else {}
    got = ops.mma_conv2d(x, w, stride=2, pad=1, planes=planes, impl=impl, **kw)
    want = ref.mma_conv2d_ref(x, w, stride=2, pad=1, planes=planes)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_conv_unsigned_path():
    """signed=False consumes uint8-valued activations (post-ReLU streams,
    the paper's native case) without the +-128 offset correction."""
    x = jnp.asarray(RNG.integers(0, 256, (1, 6, 6, 3)), jnp.uint8).astype(jnp.int32)
    w = _rand_i8((3, 3, 3, 4))
    got = ops.mma_conv2d(x.astype(jnp.int8), w, signed=True, interpret=True)
    # same values via the signed path on the offset representation
    want = ref.mma_conv2d_ref(x.astype(jnp.int8), w, signed=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_conv_unknown_impl_raises():
    x = _rand_i8((1, 4, 4, 2))
    w = _rand_i8((3, 3, 2, 2))
    with pytest.raises(ValueError):
        ops.mma_conv2d(x, w, impl="nope")
